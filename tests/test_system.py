"""End-to-end behaviour tests: trainer (both paths), fault-tolerant resume,
serving loop, and the dynamic-vs-static comparison the paper makes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import serve
from repro.launch.train import train


CFG = get_smoke_config("llama2_1b")


class TestTrainerDynamic:
    def test_loss_decreases(self):
        stats = train(CFG, steps=12, batch_size=4, mode="dynamic",
                      log_every=100)
        first = np.mean(stats["losses"][:3])
        last = np.mean(stats["losses"][-3:])
        assert last < first, (first, last)
        assert stats["recompilations"] == 0, "dynamic path must never retrace"

    def test_memory_limit_enforced(self):
        free = train(CFG, steps=4, batch_size=4, mode="dynamic", log_every=100)
        limit = int(free["peak_bytes"] * 0.7)
        lim = train(CFG, steps=4, batch_size=4, mode="dynamic",
                    memory_limit=limit, log_every=100)
        assert lim["peak_bytes"] <= limit
        # numerics unchanged by remat
        assert np.allclose(free["losses"], lim["losses"], rtol=1e-4)


class TestTrainerCompiled:
    def test_compiled_path_recompiles_per_shape(self):
        stats = train(CFG, steps=8, batch_size=4, mode="compiled",
                      data_mode="dynamic", log_every=100)
        assert stats["recompilations"] > 1  # dynamic shapes force retraces

    def test_bucketed_limits_recompiles(self):
        stats = train(CFG, steps=8, batch_size=4, mode="compiled",
                      data_mode="bucketed", log_every=100)
        assert stats["recompilations"] <= 4  # few pow2 buckets


class TestFaultTolerance:
    def test_checkpoint_resume_exact(self, tmp_path):
        d = str(tmp_path / "ck")
        full = train(CFG, steps=10, batch_size=4, mode="dynamic",
                     ckpt_dir=None, log_every=100)
        # run 10 steps with a checkpoint at 5, then "crash" and resume
        train(CFG, steps=5, batch_size=4, mode="dynamic",
              ckpt_dir=d, ckpt_every=5, log_every=100)
        resumed = train(CFG, steps=10, batch_size=4, mode="dynamic",
                        ckpt_dir=d, ckpt_every=5, log_every=100)
        # the resumed run's steps 6..10 match the uninterrupted run exactly
        assert np.allclose(full["losses"][5:], resumed["losses"], rtol=1e-5), \
            (full["losses"][5:], resumed["losses"])


class TestServe:
    @pytest.mark.parametrize("arch", ["llama2_1b", "gemma_2b",
                                      "deepseek_v3_671b", "xlstm_1p3b",
                                      "hymba_1p5b", "musicgen_medium"])
    def test_generation_runs(self, arch):
        cfg = get_smoke_config(arch)
        r = serve(cfg, batch=2, prompt_len=8, gen=4)
        if r["tokens"] is not None:
            assert r["tokens"].shape[0] == 2
        assert r["decode_tok_per_s"] > 0

    def test_greedy_deterministic(self):
        cfg = get_smoke_config("llama2_1b")
        r1 = serve(cfg, batch=2, prompt_len=8, gen=6, seed=3)
        r2 = serve(cfg, batch=2, prompt_len=8, gen=6, seed=3)
        assert np.array_equal(r1["tokens"], r2["tokens"])
