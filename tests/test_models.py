"""Per-architecture smoke tests + decode parity + mixer-math validation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)
from repro.models.attention import blockwise_attention, dense_attention
from repro.models.ssm import (SSMConfig, ssm_apply, ssm_decode_step,
                              ssm_init, ssm_init_cache)
from repro.models.xlstm import (XLSTMConfig, mlstm_apply, mlstm_chunkwise,
                                mlstm_decode_step, mlstm_init,
                                mlstm_init_cache)

RNG = np.random.RandomState(0)


def make_batch(cfg, b=2, s=16):
    if cfg.input_mode == "tokens":
        return {"tokens": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s))),
                "labels": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))}
    if cfg.input_mode == "embeddings":
        return {"frame_embed": jnp.asarray(RNG.randn(b, s, cfg.d_model),
                                           jnp.float32),
                "labels": jnp.asarray(
                    RNG.randint(0, cfg.vocab, (b, s, cfg.n_codebooks)))}
    return {"vis_embed": jnp.asarray(RNG.randn(b, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32),
            "tokens": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s))),
            "labels": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_backward(arch):
    """Reduced config: one train step on CPU, shapes + finiteness."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, _aux = forward(cfg, params, batch)
    vp = cfg.padded_vocab  # embedding tables pad to a tile boundary
    if cfg.n_codebooks:
        assert logits.shape == (2, 16, cfg.n_codebooks, vp)
    elif cfg.input_mode == "vlm":
        assert logits.shape == (2, cfg.vis_tokens + 16, vp)
    else:
        assert logits.shape == (2, 16, vp)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) config must carry the assigned numbers."""
    cfg = get_config(arch)
    expected = {
        "hymba_1p5b": (32, 1600, 25, 5, 5504, 32001),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "deepseek_v3_671b": (61, 7168, 128, 128, 2048, 129280),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
        "xlstm_1p3b": (48, 2048, 4, 4, 0, 50304),
        "llama2_1b": (4, 4096, 32, 32, 11008, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode with caches == full-sequence forward."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:  # no-drop capacity for exact parity (drops are policy)
        cfg = dataclasses.replace(cfg, moe_capacity=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, S = 2, 12
    if cfg.input_mode == "embeddings":
        fe = jnp.asarray(RNG.randn(b, S, cfg.d_model), jnp.float32)
        batch = {"frame_embed": fe}
    else:
        toks = jnp.asarray(RNG.randint(0, cfg.vocab, (b, S)))
        batch = ({"vis_embed": jnp.zeros((b, 0, cfg.d_model), jnp.float32),
                  "tokens": toks} if cfg.input_mode == "vlm"
                 else {"tokens": toks})
    full, _ = forward(cfg, params, batch)
    state = init_cache(cfg, b, max_len=S + 4)
    outs = []
    for t in range(S):
        inp = ({"frame_embed": fe[:, t:t + 1]}
               if cfg.input_mode == "embeddings"
               else {"token": toks[:, t:t + 1]})
        lg, state = decode_step(cfg, params, state, inp)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full.astype(dec.dtype)))) / \
        max(float(jnp.max(jnp.abs(full))), 1e-6)
    assert rel < 2e-2, f"decode/forward mismatch rel={rel}"


class TestAttention:
    @pytest.mark.parametrize("hq,hkv,window", [(4, 2, None), (4, 4, None),
                                               (8, 1, None), (6, 2, 24)])
    def test_blockwise_matches_dense(self, hq, hkv, window):
        q = jnp.asarray(RNG.randn(2, 96, hq, 16), jnp.float32)
        k = jnp.asarray(RNG.randn(2, 96, hkv, 16), jnp.float32)
        v = jnp.asarray(RNG.randn(2, 96, hkv, 16), jnp.float32)
        o1 = dense_attention(q, k, v, causal=True, window=window)
        o2 = blockwise_attention(q, k, v, causal=True, window=window,
                                 block_kv=32)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-4

    def test_blockwise_grads_match_dense(self):
        q = jnp.asarray(RNG.randn(2, 64, 4, 16), jnp.float32)
        k = jnp.asarray(RNG.randn(2, 64, 2, 16), jnp.float32)
        v = jnp.asarray(RNG.randn(2, 64, 2, 16), jnp.float32)
        g1 = jax.grad(lambda *a: (dense_attention(*a, causal=True) ** 2).sum(),
                      (0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda *a: (blockwise_attention(*a, causal=True,
                                                      block_kv=16) ** 2).sum(),
                      (0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4

    def test_non_divisible_seq_padding(self):
        q = jnp.asarray(RNG.randn(1, 50, 2, 8), jnp.float32)
        k = jnp.asarray(RNG.randn(1, 50, 2, 8), jnp.float32)
        v = jnp.asarray(RNG.randn(1, 50, 2, 8), jnp.float32)
        o1 = dense_attention(q, k, v, causal=True)
        o2 = blockwise_attention(q, k, v, causal=True, block_kv=16)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-4


class TestSSM:
    def test_chunked_scan_matches_stepwise(self):
        """Chunkwise selective scan == step-by-step recurrence."""
        cfg = SSMConfig(d_model=24, d_inner=48, d_state=4, chunk=8)
        params = ssm_init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(RNG.randn(2, 37, 24), jnp.float32) * 0.3
        y_full = ssm_apply(params, cfg, x)
        cache = ssm_init_cache(cfg, 2)
        ys = []
        for t in range(37):
            y, cache = ssm_decode_step(params, cfg, x[:, t:t + 1], cache)
            ys.append(y[:, 0])
        y_step = jnp.stack(ys, axis=1)
        err = float(jnp.max(jnp.abs(y_full - y_step)))
        assert err < 1e-4, err


class TestMLSTM:
    def test_chunkwise_matches_recurrent(self):
        cfg = XLSTMConfig(d_model=16, n_heads=2, proj_factor=2.0, chunk=8)
        params = mlstm_init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(RNG.randn(2, 29, 16), jnp.float32) * 0.3
        y_full = mlstm_apply(params, cfg, x)
        cache = mlstm_init_cache(cfg, 2)
        ys = []
        for t in range(29):
            y, cache = mlstm_decode_step(params, cfg, x[:, t:t + 1], cache)
            ys.append(y[:, 0])
        y_step = jnp.stack(ys, axis=1)
        err = float(jnp.max(jnp.abs(y_full - y_step)))
        assert err < 1e-3, err


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1, some tokens drop but output stays finite and
    the shared expert keeps every token covered."""
    cfg = dataclasses.replace(get_smoke_config("deepseek_v3_671b"),
                              moe_capacity=1.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, b=2, s=32)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))


def test_param_count_sane():
    cfg = get_config("granite_8b")
    n = cfg.param_count()
    assert 7.5e9 < n < 9.0e9, n
    ds = get_config("deepseek_v3_671b")
    assert 6.0e11 < ds.param_count() < 7.5e11, ds.param_count()
    assert 3.0e10 < ds.param_count(active_only=True) < 5.0e10
