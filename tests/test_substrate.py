"""Data pipeline, checkpointing (incl. elastic restore), compression,
straggler monitor, and the sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataPipeline, PipelineConfig
from repro.distributed import (StragglerMonitor, compress_gradients,
                               init_compression_state)
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.launch.sharding import ShardingRules
from repro.launch.mesh import make_debug_mesh


class TestDataPipeline:
    def test_deterministic(self):
        cfg = PipelineConfig(vocab=100, batch_size=4, seed=7)
        b1 = DataPipeline(cfg).next_batch()
        b2 = DataPipeline(cfg).next_batch()
        assert np.array_equal(b1["tokens"], b2["tokens"])

    def test_resume_exact(self):
        cfg = PipelineConfig(vocab=100, batch_size=4, seed=7)
        p = DataPipeline(cfg)
        for _ in range(5):
            p.next_batch()
        state = p.state()
        want = p.next_batch()
        q = DataPipeline(cfg)
        q.restore(state)
        got = q.next_batch()
        assert np.array_equal(want["tokens"], got["tokens"])

    def test_dynamic_shapes_vary(self):
        p = DataPipeline(PipelineConfig(vocab=100, batch_size=4, seed=1))
        shapes = {p.next_batch()["tokens"].shape[1] for _ in range(10)}
        assert len(shapes) > 3, "dynamic batching must produce varying S"

    def test_bucketed_pow2(self):
        p = DataPipeline(PipelineConfig(vocab=100, batch_size=4, seed=1,
                                        mode="bucketed"))
        for _ in range(10):
            s = p.next_batch()["tokens"].shape[1]
            assert s & (s - 1) == 0, f"{s} not a power of two"

    def test_padding_waste_ordering(self):
        p = DataPipeline(PipelineConfig(vocab=100, batch_size=14, seed=0))
        dyn, buck = p.padding_waste(50)
        assert dyn < buck, "dynamic batching must waste less than bucketing"
        assert 0 <= dyn < 0.9 and buck < 0.95

    def test_epoch_rollover(self):
        p = DataPipeline(PipelineConfig(vocab=50, batch_size=64,
                                        n_samples=100, seed=0))
        for _ in range(5):
            p.next_batch()
        assert p.state()["epoch"] >= 1


class TestCheckpointer:
    def test_save_restore_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                 "opt": {"m": np.ones(3), "step": np.int64(7)}}
        ck.save(10, state, extra={"data_cursor": 42})
        step, got, extra = ck.restore()
        assert step == 10 and extra["data_cursor"] == 42
        assert np.array_equal(got["w"], state["w"])
        assert np.array_equal(got["opt"]["m"], state["opt"]["m"])

    def test_keep_n_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": np.zeros(2)})
        assert ck.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=3)
        ck.save(5, {"x": np.arange(5)}, blocking=False)
        ck.wait()
        assert ck.latest_step() == 5

    def test_elastic_restore_new_mesh(self, tmp_path):
        """Checkpoint saved unsharded restores onto a different mesh."""
        ck = Checkpointer(str(tmp_path))
        w = np.arange(64, dtype=np.float32).reshape(8, 8)
        ck.save(1, {"w": w})
        mesh = make_debug_mesh(1, 1)  # the "new" cluster
        rules = ShardingRules(mesh)
        shard = rules.named(rules.params_pspecs(
            {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}))
        _, got, _ = ck.restore(shardings=shard)
        assert np.array_equal(np.asarray(got["w"]), w)
        assert isinstance(got["w"], jax.Array)

    def test_atomic_no_partial_on_existing(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"x": np.ones(3)})
        ck.save(1, {"x": np.zeros(3)})  # overwrite same step atomically
        _, got, _ = ck.restore(1)
        assert np.array_equal(got["x"], np.zeros(3))


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(1000), jnp.float32)
        q, s = quantize_int8(x)
        y = dequantize_int8(q, s, x.shape, jnp.float32)
        # error bounded by scale/2 per block
        assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(s)) * 0.51

    def test_error_feedback_unbiased_over_steps(self):
        """With error feedback, the accumulated compressed sum converges to
        the true gradient sum (bias is absorbed by the residual)."""
        rng = np.random.RandomState(1)
        g_true = jnp.asarray(rng.randn(512), jnp.float32) * 0.1
        grads = {"w": g_true}
        state = init_compression_state(grads)
        acc = jnp.zeros(512)
        n = 50
        for _ in range(n):
            g_hat, state = compress_gradients(grads, state)
            acc = acc + g_hat["w"]
        err = float(jnp.max(jnp.abs(acc / n - g_true)))
        assert err < 2e-3, err

    def test_compression_ratio(self):
        # int8 + fp32 scale per 256 block = ~4x fewer bytes than fp32
        x = jnp.zeros(4096, jnp.float32)
        q, s = quantize_int8(x)
        bytes_q = q.size * 1 + s.size * 4
        assert bytes_q * 3.5 < x.size * 4


class TestStraggler:
    def test_flags_persistent_straggler(self):
        mon = StragglerMonitor()
        flagged = []
        for step in range(30):
            times = {h: 1.0 + 0.01 * np.random.RandomState(step * 10 + h).rand()
                     for h in range(8)}
            if step > 10:
                times[3] = 2.5  # host 3 goes slow
            flagged += mon.record_step(times)
        assert 3 in flagged
        assert mon.healthy_hosts(list(range(8))) == [0, 1, 2, 4, 5, 6, 7]

    def test_no_false_positives(self):
        mon = StragglerMonitor()
        for step in range(30):
            times = {h: 1.0 + 0.02 * np.random.RandomState(step * 10 + h).rand()
                     for h in range(8)}
            assert mon.record_step(times) == []


class TestShardingRules:
    def test_divisible_dims_sharded(self):
        mesh = make_debug_mesh(1, 1)
        rules = ShardingRules(mesh)
        # rules are mesh-size aware; with 16-way axes these shapes shard
        from repro.launch.mesh import make_production_mesh  # noqa
        spec = rules.spec_for("layers/ffn/w1", (18, 2048, 16384))
        assert spec[0] is None  # stacked layer dim never sharded

    def test_nondivisible_falls_back(self):
        import os
        # fake a 16x16 mesh via rule object internals
        mesh = make_debug_mesh(1, 1)
        rules = ShardingRules(mesh)
        rules.model, rules.data = 16, 16
        spec = rules.spec_for("layers/attn/wq", (4608, 36 * 128))
        # 4608 % 16 == 0 -> data; 4608 cols % 16 == 0 -> model
        assert spec == jax.sharding.PartitionSpec("data", "model")
        spec2 = rules.spec_for("x/embed", (32001, 1600))
        assert spec2[0] is None  # 32001 % 16 != 0 -> replicated + recorded
        assert any("32001" in v for v in rules.fallbacks.values())

    def test_moe_expert_sharding(self):
        mesh = make_debug_mesh(1, 1)
        rules = ShardingRules(mesh)
        rules.model, rules.data = 16, 16
        spec = rules.spec_for("layers/moe/w1", (61, 256, 7168, 2048))
        assert spec == jax.sharding.PartitionSpec(None, "model", "data", None)
        dense = rules.spec_for("layers/ffn/w1", (61, 7168, 2048))
        assert dense == jax.sharding.PartitionSpec(None, "data", None) or \
            dense == jax.sharding.PartitionSpec(None, "data", "model")
