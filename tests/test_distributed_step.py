"""Compressed-gradient train step + remat-policy export."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dims
from repro.core.remat.export import recommend_policy
from repro.distributed import init_compression_state
from repro.launch.steps import adamw_config_for, make_train_step
from repro.models import init_params
from repro.optim import init_state

CFG = get_smoke_config("llama2_1b")


def _batch(b=2, s=24, seed=0):
    rng = np.random.RandomState(seed)
    t = jnp.asarray(rng.randint(0, CFG.vocab, (b, s)), jnp.int32)
    return {"tokens": t, "labels": t, "mask": jnp.ones((b, s), jnp.float32)}


class TestCompressedTrainStep:
    def test_compressed_step_close_to_exact(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_state(params, adamw_config_for(CFG))
        plain = jax.jit(make_train_step(CFG))
        comp = jax.jit(make_train_step(CFG, compress=True))
        grads_like = params
        cstate = init_compression_state(grads_like)
        batch = _batch()
        l1, p1, _ = plain(params, opt, batch)
        l2, p2, _, cstate = comp(params, opt, cstate, batch)
        assert np.allclose(float(l1), float(l2), rtol=1e-5)  # loss pre-update
        # int8-compressed update stays close to the exact one.  AdamW's
        # first step is ~sign(g)*lr, so a quantization-perturbed gradient
        # can flip near-zero entries by at most ~2*lr.
        lr = adamw_config_for(CFG).lr
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            diff = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                         - b.astype(jnp.float32))))
            assert diff <= 3 * lr, diff

    def test_error_feedback_carries_across_steps(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_state(params, adamw_config_for(CFG))
        comp = jax.jit(make_train_step(CFG, compress=True))
        cstate = init_compression_state(params)
        e0 = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(cstate.error))
        assert e0 == 0.0
        _, params, opt, cstate = comp(params, opt, cstate, _batch(seed=1))
        e1 = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(cstate.error))
        assert e1 > 0.0  # residual accumulated

    def test_grad_accum_matches_full_batch(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        opt = init_state(params, adamw_config_for(CFG))
        full = jax.jit(make_train_step(CFG))
        accum = jax.jit(make_train_step(CFG, grad_accum=2))
        batch = _batch(b=4, s=24)
        l1, p1, _ = full(params, opt, batch)
        l2, p2, _ = accum(params, opt, batch)
        assert np.allclose(float(l1), float(l2), rtol=1e-4)
        lr = adamw_config_for(CFG).lr
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            diff = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
            # summation-order noise can flip sign(g)*lr on near-zero grads
            assert float(diff.max()) <= 3 * lr, float(diff.max())
            assert float(diff.mean()) <= lr / 2


class TestRematPolicyExport:
    def test_recommendation_fields(self):
        cfg = dataclasses.replace(CFG, scan_layers=False)
        step = make_train_step(cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(params, adamw_config_for(cfg))
        B, S = symbolic_dims("b, s")
        p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt)
        bs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        fn = optimize(step, p, o, bs)
        rec = recommend_policy(fn.plan, {"b": 8, "s": 64})
        assert rec.policy_name in ("block", "dots_saveable", "none")
        assert 0.0 <= rec.recompute_flop_fraction <= 1.5
        assert 0.0 <= rec.recomputable_byte_fraction <= 1.0
        assert rec.rationale
