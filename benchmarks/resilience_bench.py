"""Resilience benchmark: disabled-path overhead contract + degradation
and recovery costs.

Measured surfaces:

* **overhead contract** — on the dispatch-chain microbench, finely
  interleaved single-call samples with resilience never attached vs
  attached-then-detached vs enabled-but-healthy (tracked as
  ``disabled_over_base`` / ``enabled_over_disabled``).  The hard <=2%
  contract is asserted on the same deterministic decomposition as
  ``obs_bench``: the disabled hot path's only added work is the
  ``self._resilience is None`` check, timed in isolation against the
  measured call (ambient A/B noise on shared runners exceeds 2%, so the
  wall ratios are tracked, not asserted);
* **degraded-path cost** — a call that takes one transient-fault retry
  vs the healthy call on the same plan (``degraded_over_healthy``; the
  floor is ~2x: the work runs twice, plus ladder bookkeeping);
* **quarantine recovery** — a bucket whose specialization is failed by
  an injected compile fault, then healed: ``recovery_s`` is the wall
  time from the fault clearing until the specialized plan is resident
  again (breaker backoff + one re-probe compile);
* **fault accounting** — a seeded mini-chaos run; ``faults_mapped_frac``
  is the fraction of fired faults that map to a structured degradation
  event or breaker transition (the chaos suite asserts 1.0; here it is
  tracked as a regression metric).

    PYTHONPATH=src python -m benchmarks.resilience_bench [--smoke] [--json F]
"""
from __future__ import annotations

import gc
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimize, symbolic_dims
from repro.core.resilience import (BreakerConfig, FaultPlan, FaultSpec,
                                   RequestFailed, ResilienceConfig,
                                   RetryPolicy)

from benchmarks.exec_bench import CHAIN_OPS

ROUNDS = 100                      # interleaved single-call samples per label
OVERHEAD_TOL = 1.02               # the <=2% contract

_NO_BACKOFF = RetryPolicy(max_retries=2, backoff_base_s=0.0)


def _chain_fn():
    n, = symbolic_dims("n")

    def chain(x):
        for _ in range(CHAIN_OPS // 2):
            x = x * 1.0000001 + 0.5
        return x

    return optimize(chain, jax.ShapeDtypeStruct((n,), jnp.float32),
                    dynamic_dims={"n": (8, 4096)})


def _overhead(rounds: int) -> Dict:
    """Resilience cost on the executor-overhead-dominated chain."""
    fn = _chain_fn()
    x = jnp.arange(64, dtype=jnp.float32)
    for _ in range(10):
        fn(x)                                    # warm: resolve + caches

    def sample() -> float:
        t0 = time.perf_counter()
        fn(x)
        return time.perf_counter() - t0

    # same interleaved min-estimator layout as obs_bench: rotating
    # label order per round, gc paused, min per label (additive noise
    # discards into the contaminated samples)
    sinks = {"base": [], "dis": [], "en": []}
    labels = ["base", "dis", "en"]
    cfg = ResilienceConfig(retry=_NO_BACKOFF)
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for r in range(rounds):
            k = r % 3
            for label in labels[k:] + labels[:k]:
                if label == "en":
                    fn.enable_resilience(cfg)
                sinks[label].append(sample())
                if label == "en":
                    assert fn.resilience.counters()["degraded_calls"] == 0
                    fn.disable_resilience()
    finally:
        if gc_was_enabled:
            gc.enable()
    base_us = min(sinks["base"]) * 1e6
    disabled_us = min(sinks["dis"]) * 1e6
    enabled_us = min(sinks["en"]) * 1e6

    # the hard contract: the disabled hot path's added work is exactly
    # one attribute load + `is None` test — time that in isolation
    n_iter = 100_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        res = fn._resilience
        if res is not None:
            raise AssertionError("resilience unexpectedly enabled")
    check_ns = (time.perf_counter() - t0) / n_iter * 1e9
    check_frac = check_ns / (disabled_us * 1e3)
    assert check_frac <= OVERHEAD_TOL - 1, (
        f"disabled-resilience check costs {check_ns:.0f}ns = "
        f"{check_frac * 100:.3f}% of a {disabled_us:.0f}us call "
        f"(contract: <=2%)")

    return dict(
        base_call_us=round(base_us, 1),
        disabled_call_us=round(disabled_us, 1),
        enabled_call_us=round(enabled_us, 1),
        disabled_check_ns=round(check_ns, 1),
        disabled_check_frac=round(check_frac, 6),
        disabled_over_base=round(disabled_us / base_us, 4),
        enabled_over_disabled=round(enabled_us / disabled_us, 4),
    )


def _degraded_cost(rounds: int) -> Dict:
    """One transient-fault retry vs the healthy call, same plan."""
    fn = _chain_fn()
    res = fn.enable_resilience(ResilienceConfig(retry=_NO_BACKOFF))
    x = jnp.arange(64, dtype=jnp.float32)
    for _ in range(10):
        fn(x)

    healthy, degraded = [], []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for r in range(rounds):
            t0 = time.perf_counter()
            fn(x)
            healthy.append(time.perf_counter() - t0)
            # arm exactly one kernel fault for the next resilient call
            fn._fault_ref.plan = FaultPlan(
                [FaultSpec("kernel", call=None, step=0)])
            t0 = time.perf_counter()
            fn(x)
            degraded.append(time.perf_counter() - t0)
            fn._fault_ref.plan = None
    finally:
        if gc_was_enabled:
            gc.enable()
    c = res.counters()
    assert c["retries_transient"] == rounds, "faults did not all fire"
    assert c["failures"] == 0
    healthy_us = min(healthy) * 1e6
    degraded_us = min(degraded) * 1e6
    return dict(
        healthy_call_us=round(healthy_us, 1),
        degraded_call_us=round(degraded_us, 1),
        degraded_over_healthy=round(degraded_us / healthy_us, 4),
    )


def _bucketed_fn(backoff_s: float):
    b, = symbolic_dims("b")

    def f(w, x):
        h = jnp.tanh(x @ w)
        return (h * h).sum()

    return optimize(f,
                    jax.ShapeDtypeStruct((8, 8), jnp.float32),
                    jax.ShapeDtypeStruct((b, 8), jnp.float32),
                    dynamic_dims={"b": (1, 512)},
                    buckets={"b": [8, 64, 512]},
                    resilience=ResilienceConfig(
                        retry=_NO_BACKOFF,
                        breaker=BreakerConfig(backoff_s=backoff_s)))


def _recovery() -> Dict:
    """Wall time from fault-clear to a healed (resident) bucket plan."""
    backoff_s = 0.02
    fn = _bucketed_fn(backoff_s)
    table = fn.specialization_table
    w = np.ones((8, 8), np.float32)
    xs = np.ones((4, 8), np.float32)
    fp = FaultPlan([FaultSpec("compile")])
    with fn.inject_faults(fp):
        fn(w, xs)                          # compile fails -> fallback
    key = fp.fired[0].bucket
    assert table.breaker.state(key) == "open"
    t0 = time.perf_counter()
    # serve traffic until the breaker re-probes and the plan lands
    while table.peek(key) is None:
        fn(w, xs)
        time.sleep(0.001)
    recovery_s = time.perf_counter() - t0
    assert table.breaker.state(key) == "closed"
    return dict(
        breaker_backoff_s=backoff_s,
        recovery_s=round(recovery_s, 4),
        degraded_calls_during_outage=fn.resilience.counters()[
            "degraded_calls"],
    )


def _mini_chaos(seeds) -> Dict:
    """Seeded fault schedules; fraction of fired faults that left a
    structured record (event, failure, or breaker transition)."""
    fired_total = mapped = 0
    calls = failures = 0
    for seed in seeds:
        fn = _bucketed_fn(0.01)
        table = fn.specialization_table
        w = np.ones((8, 8), np.float32)
        keys = [table.key_of({"b": n}) for n in (4, 32, 200)]
        plan = FaultPlan.random(seed, n_faults=4, max_call=6, max_step=2,
                                buckets=keys, timeout_delay_s=0.0)
        res = fn.resilience
        with fn.inject_faults(plan):
            for i in range(6):
                xs = np.ones(((4, 32, 200)[i % 3], 8), np.float32)
                calls += 1
                try:
                    fn(w, xs)
                except RequestFailed:
                    failures += 1
        evs = list(res.events)
        for f in plan.fired:
            fired_total += 1
            if f.kind in ("compile", "compile-timeout"):
                ok = any(t["key"] == f.bucket and t["state"] == "open"
                         for t in table.breaker.transitions)
            else:
                ok = any(e.seq == f.call for e in evs)
            mapped += bool(ok)
    return dict(
        chaos_seeds=list(seeds),
        chaos_calls=calls,
        chaos_fired=fired_total,
        chaos_failures=failures,
        faults_mapped_frac=round(mapped / fired_total, 4)
        if fired_total else 1.0,
    )


def run(smoke: bool = False) -> List[Dict]:
    rounds = 20 if smoke else ROUNDS
    row = dict(arch="resilience_micro", n_ops=CHAIN_OPS)
    row.update(_overhead(rounds))
    row.update(_degraded_cost(max(10, rounds // 2)))
    row.update(_recovery())
    row.update(_mini_chaos((0,) if smoke else (0, 1, 2)))
    row["smoke"] = smoke       # bench_regress doubles tolerance for smoke
    return [row]


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        out.append(
            f"{r['arch']:18s} check={r['disabled_check_ns']:.0f}ns "
            f"({100 * r['disabled_check_frac']:.4f}% of call, "
            f"contract <=2%) degraded/healthy="
            f"{r['degraded_over_healthy']:.2f}x "
            f"recovery={r['recovery_s'] * 1e3:.0f}ms "
            f"faults_mapped={100 * r['faults_mapped_frac']:.0f}%")
    return "\n".join(out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds and chaos seeds (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
