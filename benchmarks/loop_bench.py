"""Rolled-loop benchmark: plan size, compile time and per-step execution
cost of symbolic control flow vs the mechanically unrolled DAG.

For each benchmark arch a small autoregressive decode cell (the arch's
smoke ``d_model`` and input mode, mirroring ``tests/test_loops.py``) is
compiled two ways: **rolled** — one ``jax.lax.scan`` with a symbolic
trip count ``t``, one ``Loop`` instruction — and **unrolled** — a Python
loop at static trip count T, an O(T·body) instruction stream.

Asserted invariants (the symbolic-control-flow contract):

  * plan size is independent of the trip count: the rolled program's
    instruction counts are identical under a 64x wider declared trip
    range, and strictly smaller than the unrolled program at T=17;
  * compile time is independent of the trip count: compiling the rolled
    loop under the wide range costs no more than 2.5x the narrow range
    (noise bound), while the unrolled compile grows with T;
  * rolled per-step execution cost <= unrolled per-step cost (25%
    noise bound) at T=17.

    PYTHONPATH=src python -m benchmarks.loop_bench [--smoke] [--json F]
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dim

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
SMOKE_ARCHS = ["llama2_1b", "musicgen_medium"]   # both input modes

B = 2
V = 32
T_EXEC = 17
NARROW = (1, 64)
WIDE = (1, 4096)
N_CALLS = 12


def _cell(arch):
    """Decode cell for one arch: (step, param_specs, xs_spec_fn)."""
    cfg = get_smoke_config(arch)
    d = cfg.d_model
    tokens = cfg.input_mode == "tokens"

    def step(params, c, x):
        e = params["emb"][x] if tokens else x @ params["wx"]
        h = jnp.tanh(c @ params["wh"] + e)
        return h, jnp.sum(h, axis=-1)

    p = {"wh": jax.ShapeDtypeStruct((d, d), jnp.float32),
         "h0": jax.ShapeDtypeStruct((B, d), jnp.float32)}
    if tokens:
        p["emb"] = jax.ShapeDtypeStruct((V, d), jnp.float32)
        xs_spec = lambda t: jax.ShapeDtypeStruct((t, B), jnp.int32)
    else:
        p["wx"] = jax.ShapeDtypeStruct((d, d), jnp.float32)
        xs_spec = lambda t: jax.ShapeDtypeStruct((t, B, d), jnp.float32)
    return step, p, xs_spec


def _rolled_fn(arch):
    step, _, _ = _cell(arch)

    def f(params, xs):
        c0 = jnp.tanh(params["h0"])
        cN, ys = jax.lax.scan(lambda c, x: step(params, c, x), c0, xs)
        return cN, ys
    return f


def _unrolled_fn(arch, T):
    step, _, _ = _cell(arch)

    def f(params, xs):
        c = jnp.tanh(params["h0"])
        ys = []
        for i in range(T):
            c, y = step(params, c, xs[i])
            ys.append(y)
        return c, jnp.stack(ys)
    return f


def _concrete(arch, T, seed=0):
    _, p_specs, xs_spec = _cell(arch)
    rng = np.random.RandomState(seed)
    params = {k: jnp.asarray(rng.randn(*s.shape) * 0.2, s.dtype)
              for k, s in p_specs.items()}
    xs = xs_spec(T)
    if np.issubdtype(xs.dtype, np.integer):
        xv = jnp.asarray(rng.randint(0, V, xs.shape), xs.dtype)
    else:
        xv = jnp.asarray(rng.randn(*xs.shape) * 0.2, xs.dtype)
    return params, xv


def _best_wall_us(fn, n: int = N_CALLS) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _compile_us(build) -> float:
    t0 = time.perf_counter()
    fn = build()
    return fn, (time.perf_counter() - t0) * 1e6


def _bench_arch(arch: str) -> Dict:
    t = symbolic_dim("t")
    _, p_specs, xs_spec = _cell(arch)

    rolled, narrow_us = _compile_us(lambda: optimize(
        _rolled_fn(arch), p_specs, xs_spec(t), dynamic_dims={"t": NARROW}))
    t2 = symbolic_dim("t")
    wide, wide_us = _compile_us(lambda: optimize(
        _rolled_fn(arch), p_specs, xs_spec(t2), dynamic_dims={"t": WIDE}))
    unrolled, unrolled_us = _compile_us(lambda: optimize(
        _unrolled_fn(arch, T_EXEC), p_specs, xs_spec(T_EXEC)))

    counts = rolled.program.counts()
    assert counts["Loop"] == 1
    assert wide.program.counts() == counts, (
        f"{arch}: rolled plan size depends on the declared trip range")
    n_rolled = rolled.program.n_instructions
    n_unrolled = unrolled.program.n_instructions
    assert n_rolled < n_unrolled, (
        f"{arch}: rolled program ({n_rolled}) not smaller than unrolled "
        f"({n_unrolled}) at T={T_EXEC}")
    assert wide_us <= narrow_us * 2.5 + 50_000, (
        f"{arch}: rolled compile time grew with the trip range "
        f"({narrow_us:.0f}us -> {wide_us:.0f}us)")

    params, xs = _concrete(arch, T_EXEC)
    rolled(params, xs)                    # warm: resolve + caches
    unrolled(params, xs)
    rolled_us = _best_wall_us(lambda: rolled(params, xs))
    unrolled_wall_us = _best_wall_us(lambda: unrolled(params, xs))
    assert rolled_us <= unrolled_wall_us * 1.25, (
        f"{arch}: rolled per-step cost {rolled_us / T_EXEC:.1f}us clearly "
        f"above unrolled {unrolled_wall_us / T_EXEC:.1f}us")

    return dict(
        arch=arch,
        n_instructions_rolled=n_rolled,
        n_instructions_unrolled=n_unrolled,
        compile_rolled_us=round(narrow_us, 1),
        compile_rolled_wide_us=round(wide_us, 1),
        compile_unrolled_us=round(unrolled_us, 1),
        exec_rolled_us=round(rolled_us, 1),
        exec_unrolled_us=round(unrolled_wall_us, 1),
        per_step_rolled_us=round(rolled_us / T_EXEC, 2),
        per_step_unrolled_us=round(unrolled_wall_us / T_EXEC, 2),
        # dimensionless metrics for tools/bench_regress.py
        compile_speedup_vs_unrolled=round(unrolled_us / narrow_us, 3),
        exec_speedup_vs_unrolled=round(unrolled_wall_us / rolled_us, 3),
        plan_size_ratio=round(n_unrolled / n_rolled, 3),
    )


def run(smoke: bool = False) -> List[Dict]:
    rows = []
    for arch in (SMOKE_ARCHS if smoke else ARCHS):
        row = _bench_arch(arch)
        row["smoke"] = smoke   # bench_regress doubles tolerance for smoke
        rows.append(row)
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        out.append(
            f"{r['arch']:18s} program {r['n_instructions_rolled']:3d} vs "
            f"{r['n_instructions_unrolled']:3d} instrs "
            f"({r['plan_size_ratio']:.1f}x)  "
            f"compile {r['compile_rolled_us']:8.0f}us vs "
            f"{r['compile_unrolled_us']:8.0f}us "
            f"({r['compile_speedup_vs_unrolled']:.1f}x)  "
            f"step {r['per_step_rolled_us']:6.1f}us vs "
            f"{r['per_step_unrolled_us']:6.1f}us")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="two archs (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
