"""Value-dependent bounded dims: reserved-at-the-cap vs bound-tight runtime.

The planner reserves every bounded slot at its cap expression (the only
sound compile-time answer), but each call's ``BindDim`` publishes the
measured extent so later fits, frees, and peaks use the *tight* size.
This bench quantifies the gap on two packed-sequence-style archs:

* **ragged_ffn** — a masked row-selection (``masked_select``) feeding a
  per-row FFN (matmul + tanh): the classic "run the expensive layer only
  on valid rows" serving pattern, where the bounded intermediates are 4x
  wider than anything pre-selection;
* **filter_topk** — a value filter chained into a ``topk_dynamic`` whose
  cap is itself a bounded dim: two stacked introducers.

Per occupancy level the measured device peak is compared against the
pad-to-bound peak (the same program replayed with every bounded dim at
its cap — what a runtime without BindDim would have to account).
Asserted, not just tracked:

* ``tight_over_pad`` is monotone non-increasing as occupancy drops —
  the reserved-vs-actual ratio *improves* as fill drops;
* tight frees beat pad-to-bound strictly below full occupancy;
* at every occupancy the runtime arena stays under the plan's
  cap-derived ``arena_bound_bytes`` reserve.

``tight_over_pad_half`` / ``tight_over_pad_empty`` (dimensionless,
deterministic accounting) are the regression metrics.

    PYTHONPATH=src python -m benchmarks.bounded_bench [--smoke] [--json F]
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimize, symbolic_dim
from repro.kernels import masked_select, topk_dynamic

DIM_RANGE = (1, 256)
# same n in smoke and full runs: the tight/pad ratios scale with n (the
# fixed-size inputs stop mattering as n grows), so regress comparisons of
# a fresh smoke vs the committed full run must share the anchor.  The
# expensive part is the one compile per arch, identical either way —
# smoke only trims the occupancy sweep.
N_ROWS = 192
OCCUPANCIES = [1.0, 0.75, 0.5, 0.25, 0.0]
SMOKE_OCCUPANCIES = [1.0, 0.5, 0.0]


def _ragged_ffn():
    s = symbolic_dim("s")

    def f(x, mask, w):
        rows, cnt = masked_select(x, mask)      # (b, 16): kept rows only
        h = jnp.tanh(rows @ w)                  # (b, 64): bounded, dominant
        return jnp.sum(h, axis=0), cnt

    specs = (jax.ShapeDtypeStruct((s, 16), jnp.float32),
             jax.ShapeDtypeStruct((s,), jnp.bool_),
             jax.ShapeDtypeStruct((16, 64), jnp.float32))
    return f, specs


def _filter_topk():
    s = symbolic_dim("s")

    def f(x, mask, k):
        y, cnt = masked_select(x, mask)
        v, kept = topk_dynamic(y * 2.0, k)
        return jnp.cumsum(v), cnt, kept

    specs = (jax.ShapeDtypeStruct((s,), jnp.float32),
             jax.ShapeDtypeStruct((s,), jnp.bool_),
             jax.ShapeDtypeStruct((), jnp.int32))
    return f, specs


ARCHS = {"ragged_ffn": _ragged_ffn, "filter_topk": _filter_topk}


def _mask(n: int, occ: float) -> jnp.ndarray:
    # exact occupancy (a prefix mask), so the 0% and 100% edges are exact
    # and the measured extent is occ*n to within rounding
    keep = int(round(n * occ))
    return jnp.arange(n) < keep


def _args_for(arch: str, n: int, occ: float):
    rng = np.random.RandomState(n)
    if arch == "ragged_ffn":
        return (jnp.asarray(rng.randn(n, 16), jnp.float32), _mask(n, occ),
                jnp.asarray(rng.randn(16, 64) * 0.1, jnp.float32))
    return (jnp.asarray(rng.randn(n), jnp.float32), _mask(n, occ),
            jnp.int32(n))


def _arch_row(arch: str, n: int, occs: List[float]) -> Dict:
    f, specs = ARCHS[arch]()
    fn = optimize(f, *specs, dynamic_dims={"s": DIM_RANGE})

    # pad-to-bound baseline: the same program with every bounded dim at
    # its cap — replayed accounting, the counterfactual without BindDim
    pad_peak = fn.memory_timeline({"s": n}).actual.peak_device

    occ_rows = []
    tight_over_pad: Dict[float, float] = {}
    for occ in occs:
        fn(*_args_for(arch, n, occ))
        st = fn.last_report.stats
        ratio = st.device_peak / pad_peak
        tight_over_pad[occ] = ratio
        assert st.arena_bytes <= fn.report.arena_bound_bytes, (
            f"{arch}@occ={occ}: arena {st.arena_bytes} over reserve "
            f"{fn.report.arena_bound_bytes}")
        occ_rows.append(dict(occupancy=occ,
                             measured=dict(st.measured_dims),
                             device_peak=st.device_peak,
                             tight_over_pad=round(ratio, 4)))

    # the reserved-vs-actual ratio improves (monotonically) as fill drops
    ordered = sorted(occs, reverse=True)
    for hi_occ, lo_occ in zip(ordered, ordered[1:]):
        assert tight_over_pad[lo_occ] <= tight_over_pad[hi_occ] + 1e-9, (
            f"{arch}: tight/pad worsened from occ={hi_occ} "
            f"({tight_over_pad[hi_occ]:.4f}) to occ={lo_occ} "
            f"({tight_over_pad[lo_occ]:.4f})")
    # tight frees strictly beat pad-to-bound below full occupancy
    for occ, r in tight_over_pad.items():
        if occ < 1.0:
            assert r < 1.0, f"{arch}@occ={occ}: tight peak {r:.4f}x pad"

    def _at(occ: float) -> Optional[float]:
        r = tight_over_pad.get(occ)
        return round(r, 4) if r is not None else None

    return dict(
        arch=arch,
        n=n,
        pad_peak_bytes=pad_peak,
        arena_bound_bytes=fn.report.arena_bound_bytes,
        occupancies=occ_rows,
        tight_over_pad_full=_at(1.0),
        tight_over_pad_half=_at(0.5),
        tight_over_pad_empty=_at(0.0),
    )


def run(smoke: bool = False) -> List[Dict]:
    occs = SMOKE_OCCUPANCIES if smoke else OCCUPANCIES
    rows = [_arch_row(arch, N_ROWS, occs) for arch in ARCHS]
    for r in rows:
        r["smoke"] = smoke   # bench_regress doubles tolerance for smoke rows
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        occ_txt = " ".join(
            f"occ{int(100 * o['occupancy'])}={o['tight_over_pad']:.3f}"
            for o in r["occupancies"])
        out.append(
            f"{r['arch']:14s} n={r['n']:4d} pad={r['pad_peak_bytes']:8d}B "
            f"reserve={r['arena_bound_bytes']:8d}B  {occ_txt}")
    return "\n".join(out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller n, three occupancies (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
