"""Rematerialization sweep (paper §2.3): peak memory + recompute overhead
as the memory limit tightens, on the Llama train step with dynamic shapes."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dims
from repro.core.executor.memory import MemoryLimitExceeded
from repro.launch.steps import adamw_config_for, make_train_step
from repro.models import init_params
from repro.optim import init_state


def run(fractions=(1.0, 0.85, 0.7, 0.6, 0.55), steps: int = 3) -> List[Dict]:
    cfg = dataclasses.replace(get_smoke_config("llama2_1b"), scan_layers=False)
    step = make_train_step(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params, adamw_config_for(cfg))
    B, S = symbolic_dims("b, s")
    p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)
    batch_spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                  "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    opt = optimize(step, p, o, batch_spec)

    rng = np.random.RandomState(0)
    batches = []
    for i in range(steps):
        b, s = 4, int(40 + 24 * i)
        t = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
        batches.append({"tokens": t, "labels": t,
                        "mask": jnp.ones((b, s), jnp.float32)})
    # free-run peak
    peak = 0
    for bt in batches:
        opt(params, opt_state, bt)
        peak = max(peak, opt.last_report.stats.device_peak)

    rows: List[Dict] = []
    for frac in fractions:
        lim = opt.with_memory_limit(int(peak * frac))
        rec: Dict = dict(fraction=frac, limit=int(peak * frac), peak=0,
                         evictions=0, recomputes=0, offloads=0,
                         recompute_flops=0, ok=True)
        try:
            for bt in batches:
                lim(params, opt_state, bt)
                st = lim.last_report.stats
                rec["peak"] = max(rec["peak"], st.device_peak)
                rec["evictions"] += st.evictions
                rec["recomputes"] += st.recomputes
                rec["offloads"] += st.offloads
                rec["recompute_flops"] += st.recompute_flops
        except MemoryLimitExceeded:
            rec["ok"] = False
        rows.append(rec)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"limit={100*r['fraction']:4.0f}%  peak={r['peak']/2**20:7.1f} MiB  "
              f"evict={r['evictions']:3d} recompute={r['recomputes']:3d} "
              f"offload={r['offloads']:3d} extra_flops={r['recompute_flops']:.2e} "
              f"{'ok' if r['ok'] else 'OOM'}")
