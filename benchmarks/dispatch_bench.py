"""Dispatch benchmark: per-call bucket-dispatch overhead + bucketed vs
monolithic guaranteed memory, across the 4 benchmark archs.

For each arch, ``optimize`` the train step once with symbolic ``(b, s)``
over ``b ∈ [1, 64]``, ``s ∈ [16, 4096]`` and sequence-length buckets, then
measure:

  * ``mono_arena_bound`` / ``mono_peak_bound`` — the whole-range plan's
    guaranteed arena / peak bytes (what a bucket-less deployment must
    provision for *every* request);
  * per bucket: the specialized plan's ``arena_bound_bytes`` /
    ``peak_bound_bytes`` and its ``cmp_stats`` symbolic fraction;
  * ``dispatch_p50_ns`` — median hit-path dispatch cost (bucket-key bisect
    + table probe), measured over repeated lookups of a resident bucket.

Asserted invariants (the dispatch contract):

  * at least one bucket's ``arena_bound_bytes`` is strictly below the
    whole-range bound on every arch — specialization pays somewhere;
  * no bucket's bound exceeds the whole-range bound — it never loses;
  * the hit path never re-plans: ``specialize_count`` is unchanged by
    repeated lookups of already-compiled buckets.

    PYTHONPATH=src python -m benchmarks.dispatch_bench [--smoke] [--json F]
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import optimize

from benchmarks.memplan_bench import _step_and_specs

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
SMOKE_ARCHS = ["llama2_1b", "musicgen_medium"]   # both input modes

BATCH_RANGE = (1, 64)
SEQ_RANGE = (16, 4096)
BUCKET_EDGES = {"s": [64, 512]}          # s: [16,64] [65,512] [513,4096]
SMOKE_BUCKET_EDGES = {"s": [512]}        # s: [16,512] [513,4096]
N_LOOKUPS = 2000


def _dispatch_p50_ns(table, env: Dict[str, int], n: int = N_LOOKUPS) -> int:
    """Median wall time of the hit path: key bisect + LRU probe."""
    table.get(table.key_of(env))         # make the bucket resident
    samples = []
    for _ in range(n):
        t0 = time.perf_counter_ns()
        _, hit = table.lookup(env)
        samples.append(time.perf_counter_ns() - t0)
        assert hit, "dispatch bench env unexpectedly missed its bucket"
    samples.sort()
    return samples[len(samples) // 2]


def run(smoke: bool = False) -> List[Dict]:
    archs = SMOKE_ARCHS if smoke else ARCHS
    edges = SMOKE_BUCKET_EDGES if smoke else BUCKET_EDGES
    rows = []
    for arch in archs:
        r = _step_and_specs(arch)
        if r is None:
            continue
        step, args = r
        fn = optimize(step, *args,
                      dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE},
                      buckets=edges)
        table = fn.specialization_table
        mono = fn.report

        buckets = []
        for key in table.space.keys():
            bp = table.get(key)
            buckets.append(dict(
                key=list(key), label=table.space.describe(key),
                arena_bound_bytes=bp.arena_bound_bytes,
                peak_bound_bytes=bp.report.peak_bound_bytes,
                cmp_symbolic_fraction=round(
                    bp.report.cmp_symbolic_fraction, 4),
            ))
        spec_before = table.specialize_count

        b_bounds = [b["arena_bound_bytes"] for b in buckets]
        assert min(b_bounds) < mono.arena_bound_bytes, \
            f"{arch}: no bucket beats the whole-range arena bound"
        assert max(b_bounds) <= mono.arena_bound_bytes, \
            f"{arch}: a bucket's bound exceeds the whole-range bound"

        # hit-path overhead in each bucket, via a representative env
        p50s = []
        for key in table.space.keys():
            ranges = table.space.ranges_of(key)
            env = {name: iv.lo for name, iv in ranges.items()}
            p50s.append(_dispatch_p50_ns(table, env))
        assert table.specialize_count == spec_before, \
            f"{arch}: cached-bucket dispatch re-ran the pipeline"

        rows.append(dict(
            arch=arch,
            n_buckets=table.n_buckets,
            mono_arena_bound=mono.arena_bound_bytes,
            mono_peak_bound=mono.peak_bound_bytes,
            mono_cmp_symbolic_fraction=round(mono.cmp_symbolic_fraction, 4),
            buckets=buckets,
            min_bucket_over_mono=round(
                min(b_bounds) / mono.arena_bound_bytes, 4),
            dispatch_p50_ns=max(p50s),
            specialize_count=table.specialize_count,
        ))
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        out.append(
            f"{r['arch']:18s} mono arena<= {r['mono_arena_bound']/2**20:9.1f}"
            f"MiB  symfrac={100*r['mono_cmp_symbolic_fraction']:.1f}%  "
            f"dispatch p50={r['dispatch_p50_ns']/1e3:.1f}us")
        for b in r["buckets"]:
            frac = b["arena_bound_bytes"] / r["mono_arena_bound"]
            out.append(
                f"    {b['label']:24s} arena<= "
                f"{b['arena_bound_bytes']/2**20:9.1f}MiB ({frac:6.1%})  "
                f"symfrac={100*b['cmp_symbolic_fraction']:.1f}%")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two archs, two buckets (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
