"""Compile-path benchmark: cold vs incremental bucket specialization +
background-specialization miss-path latency, across the 4 bench archs.

Three questions, matching the three layers of the fast compile path:

1. **Incremental specialization** — per sequence-length bucket, how long
   does the schedule → remat → memplan pipeline take *cold* (a fresh
   ``ShapeGraph``, empty memo tables, no shared expression caches — what
   a bucket miss cost before the incremental subsystem) vs *incremental*
   (``ShapeGraph.specialized`` verdict inheritance + the whole-range
   compile's :class:`~repro.core.api.PipelineArtifacts`: shared
   impact/flops expression caches, per-candidate remat reuse, schedule
   post-pass reuse)?  ``speedup = cold / incremental`` per bucket,
   median-of-N timing.

2. **Scheduler hot loop** — ``OpScheduler.schedule()`` with the
   incremental impact cache vs the legacy per-step recomputation
   (``incremental_impact=False``) on the same graph + shape graph.

3. **Miss-path latency** — with ``background_specialize=True``, a cold
   bucket miss must NOT run the pipeline on the request thread: the
   first call in an uncompiled bucket is timed against a hit-path call
   in the same bucket after the background compile lands.

Asserted contract (the PR's acceptance bar):

  * mean incremental speedup >= 2x on >= 3 of the 4 archs;
  * miss-path request latency <= 2x hit-path latency on every measured
    arch (the fallback serve pays dispatch + whole-range execution, never
    a synchronous pipeline);
  * background and synchronous specialization produce identical
    ``specialize_count`` once drained.

    PYTHONPATH=src python -m benchmarks.compile_bench [--smoke] [--json F]
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import optimize
from repro.core.api import _compile_pipeline
from repro.core.ir.trace import trace_to_graph
from repro.core.scheduling.scheduler import OpScheduler
from repro.core.symbolic import ShapeGraph, declare_dim_ranges

from benchmarks.memplan_bench import _step_and_specs

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
SMOKE_ARCHS = ["llama2_1b", "musicgen_medium"]   # both input modes

BATCH_RANGE = (1, 64)
SEQ_RANGE = (16, 4096)
BUCKET_RANGES = [(16, 64), (65, 512), (513, 4096)]
SMOKE_BUCKET_RANGES = [(16, 64), (513, 4096)]
REPEATS = 3
SMOKE_REPEATS = 1

MIN_SPEEDUP = 2.0          # per-arch mean, needed on >= 3 of 4 archs
MIN_ARCHS_AT_SPEEDUP = 3
MAX_MISS_OVER_HIT = 2.0


def _median_time(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _bench_buckets(graph, repeats: int, bucket_ranges) -> Dict:
    """Cold vs incremental per-bucket pipeline times for one traced graph."""
    sg = ShapeGraph()
    declare_dim_ranges(sg, {"b": BATCH_RANGE, "s": SEQ_RANGE})
    t0 = time.perf_counter()
    _plan, _report, artifacts = _compile_pipeline(graph, sg, collect=True)
    mono_s = time.perf_counter() - t0

    buckets = []
    for lo, hi in bucket_ranges:
        def run_cold(lo=lo, hi=hi):
            cold_sg = ShapeGraph()
            declare_dim_ranges(cold_sg, {"b": BATCH_RANGE, "s": (lo, hi)})
            _compile_pipeline(graph, cold_sg)

        def run_inc(lo=lo, hi=hi):
            sub = sg.specialized({"s": (lo, hi)})
            _compile_pipeline(graph, sub, parent=artifacts)

        cold_s = _median_time(run_cold, repeats)
        inc_s = _median_time(run_inc, repeats)
        # observability: reuse level + memo split of one incremental run
        sub = sg.specialized({"s": (lo, hi)})
        _, rep, _ = _compile_pipeline(graph, sub, parent=artifacts)
        buckets.append(dict(
            s_range=[lo, hi], cold_s=round(cold_s, 4),
            incremental_s=round(inc_s, 4),
            speedup=round(cold_s / inc_s, 3),
            reused_schedule=rep.reused_parent_schedule,
            reused_postpass=rep.reused_parent_postpass,
            cmp_cache_hit=rep.cmp_stats.get("cache_hit", 0),
            cmp_cache_miss=rep.cmp_stats.get("cache_miss", 0),
            cmp_inherited=rep.cmp_stats.get("inherited", 0),
        ))
    speedups = [b["speedup"] for b in buckets]
    return dict(mono_s=round(mono_s, 4), buckets=buckets,
                mean_speedup=round(sum(speedups) / len(speedups), 3))


class _NullCache(dict):
    """A cache that never retains — emulates the pre-PR scheduler, which
    rebuilt every impact polynomial on every recomputation."""

    def __setitem__(self, key, value):
        pass


def _bench_scheduler(graph, repeats: int) -> Dict:
    """Incremental impact maintenance vs the legacy hot loop (per-step
    recomputation, no polynomial memoization)."""
    def run(incremental: bool, cache=None):
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": BATCH_RANGE, "s": SEQ_RANGE})
        OpScheduler(graph, sg, incremental_impact=incremental,
                    impact_expr_cache=cache).schedule()

    inc_s = _median_time(lambda: run(True), repeats)
    naive_s = _median_time(lambda: run(False, _NullCache()), repeats)
    # differential guard: both modes must produce the identical order
    sg1, sg2 = ShapeGraph(), ShapeGraph()
    for g_ in (sg1, sg2):
        declare_dim_ranges(g_, {"b": BATCH_RANGE, "s": SEQ_RANGE})
    o1 = OpScheduler(graph, sg1).schedule()
    o2 = OpScheduler(graph, sg2, incremental_impact=False).schedule()
    assert [n.id for n in o1.order] == [n.id for n in o2.order], \
        "incremental impact cache changed the schedule"
    return dict(incremental_s=round(inc_s, 4), naive_s=round(naive_s, 4),
                speedup=round(naive_s / inc_s, 3))


def _bench_miss_path(step, args) -> Dict:
    """Request latency on a cold-bucket miss with background specialization
    vs a hit, plus the sync-vs-background specialize_count contract."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import gc

    rng = np.random.RandomState(0)

    def concrete(spec, b, s):
        dims = tuple(b if d == "b" else s if d == "s" else d
                     for d in (str(d) if not isinstance(d, int) else d
                               for d in spec.shape))
        if spec.dtype == jnp.int32:
            return jnp.asarray(rng.randint(1, 100, dims), jnp.int32)
        return jnp.asarray(rng.randn(*dims), jnp.float32)

    # three buckets -> three true cold misses and three first-env hits:
    # ratios of medians, not of two single samples.  Every measured call is
    # a *first request for its env*, so miss and hit each pay exactly one
    # per-env resolve (fair comparison)
    edges = [256, 512]
    miss_ss = [32, 300, 600]               # one env per bucket
    hit_ss = [48, 320, 640]
    make = lambda s: jax.tree.map(lambda sp: concrete(sp, 16, s), args)
    miss_argss = [make(s) for s in miss_ss]
    hit_argss = [make(s) for s in hit_ss]

    # sync reference first: specializes every bucket synchronously AND
    # warms the global XLA op cache for these concrete shapes, so the
    # measurements below isolate the serving cost (dispatch + plan
    # execution) from one-time op compilation
    fn_sync = optimize(step, *args,
                       dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE},
                       buckets={"s": edges})
    outs_sync = [fn_sync(*a) for a in miss_argss]
    for a in hit_argss:
        fn_sync(*a)

    fn = optimize(step, *args,
                  dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE},
                  buckets={"s": edges},
                  background_specialize=True)
    table = fn.specialization_table

    # cold misses: served by the whole-range fallback, compiles background
    misses, outs_miss = [], []
    for a in miss_argss:
        gc.collect()
        t0 = time.perf_counter()
        outs_miss.append(fn(*a))
        misses.append(time.perf_counter() - t0)
    assert table.fallback_serves >= len(miss_ss), \
        "misses did not use the fallback plan"

    # deterministic join, then first-request-in-env hits per compiled bucket
    fn.drain_specializations()
    hits = []
    for a in hit_argss:
        gc.collect()
        t0 = time.perf_counter()
        fn(*a)
        hits.append(time.perf_counter() - t0)
    outs_hit = [fn(*a) for a in miss_argss]    # specialized, miss envs

    # identical outputs: sync (specialized), miss (fallback plan), and the
    # post-swap specialized run must agree bitwise on the same inputs
    for o_sync, o_miss, o_hit in zip(outs_sync, outs_miss, outs_hit):
        for a, b, c in zip(jax.tree.leaves(o_sync), jax.tree.leaves(o_miss),
                           jax.tree.leaves(o_hit)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes() \
                == np.asarray(c).tobytes(), \
                "fallback-served output differs from specialized output"

    assert table.specialize_count == \
        fn_sync.specialization_table.specialize_count, \
        "background specialize_count diverges from synchronous"

    miss_s = sorted(misses)[len(misses) // 2]
    hit_s = sorted(hits)[len(hits) // 2]
    return dict(miss_ms=round(miss_s * 1e3, 3), hit_ms=round(hit_s * 1e3, 3),
                miss_over_hit=round(miss_s / hit_s, 3),
                specialize_count=table.specialize_count)


def run(smoke: bool = False) -> List[Dict]:
    archs = SMOKE_ARCHS if smoke else ARCHS
    repeats = SMOKE_REPEATS if smoke else REPEATS
    bucket_ranges = SMOKE_BUCKET_RANGES if smoke else BUCKET_RANGES
    rows = []
    for arch in archs:
        r = _step_and_specs(arch)
        if r is None:
            continue
        step, args = r
        graph, _ = trace_to_graph(step, *args)
        row = dict(arch=arch, n_nodes=len(graph.nodes), smoke=smoke)
        row.update(_bench_buckets(graph, repeats, bucket_ranges))
        row["scheduler"] = _bench_scheduler(graph, repeats)
        row["miss_path"] = _bench_miss_path(step, args)
        # timing asserts hold medians to the contract on the full run only;
        # smoke medians are single samples on shared CI runners
        if not smoke:
            assert row["miss_path"]["miss_over_hit"] <= MAX_MISS_OVER_HIT, \
                (f"{arch}: miss-path latency "
                 f"{row['miss_path']['miss_over_hit']}x the hit path — "
                 f"pipeline ran on the request thread?")
        rows.append(row)

    fast_enough = sum(1 for r in rows if r["mean_speedup"] >= MIN_SPEEDUP)
    # smoke mode runs 1 repetition on 2 archs — assert the full contract
    # only on the full run, where medians are stable
    if not smoke:
        assert fast_enough >= MIN_ARCHS_AT_SPEEDUP, \
            (f"incremental specialization >= {MIN_SPEEDUP}x on only "
             f"{fast_enough}/{len(rows)} archs: "
             f"{[(r['arch'], r['mean_speedup']) for r in rows]}")
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        sch = r["scheduler"]
        mp = r["miss_path"]
        out.append(
            f"{r['arch']:18s} mono {r['mono_s']*1e3:7.0f} ms   "
            f"incremental mean {r['mean_speedup']:.2f}x   "
            f"scheduler {sch['speedup']:.2f}x   "
            f"miss/hit {mp['miss_over_hit']:.2f}x")
        for b in r["buckets"]:
            lo, hi = b["s_range"]
            level = "full" if b["reused_schedule"] else \
                "postpass" if b["reused_postpass"] else "re-run"
            out.append(
                f"    s=[{lo:5d},{hi:5d}]  cold {b['cold_s']*1e3:7.0f} ms  "
                f"inc {b['incremental_s']*1e3:7.0f} ms  "
                f"({b['speedup']:.2f}x, {level}, "
                f"inherited={b['cmp_inherited']})")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two archs, two buckets, one repetition (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
