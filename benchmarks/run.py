"""Benchmark harness: one entry per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention and
writes ``BENCH_memplan.json`` (peak/arena/bound per arch),
``BENCH_dispatch.json`` (bucketed vs monolithic bounds, dispatch overhead)
and ``BENCH_exec.json`` (VM vs reference executor: per-call wall + per-op
dispatch overhead) so the planner's, dispatcher's and executor's
trajectories are machine-trackable across PRs.
    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
import argparse
import json
import sys
import time


def _timed(name, fn, derived_fn):
    t0 = time.time()
    rows = fn()
    dt = (time.time() - t0) * 1e6
    print(f"{name},{dt:.0f},{derived_fn(rows)}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller step counts (CI)")
    args = ap.parse_args()
    steps = 6 if args.fast else 12

    from benchmarks import (bounded_bench, compile_bench, dispatch_bench,
                            exec_bench, kernel_bench, loop_bench,
                            memplan_bench, obs_bench, remat_sweep,
                            resilience_bench, roofline,
                            scheduler_micro, symbolic_coverage,
                            table1_dynamic_training)

    # paper Table 1: dynamic vs static vs BladeDISC++ training
    rows = _timed(
        "table1_dynamic_training",
        lambda: table1_dynamic_training.run(steps=steps),
        lambda rs: ";".join(
            f"{r['system']}@b{r['batch']}:"
            + ("OOM" if r["oom"] else f"{r['peak']/2**20:.0f}MiB")
            for r in rs))
    print(table1_dynamic_training.format_rows(rows), file=sys.stderr)

    # §2.2: scheduling peak-memory reductions
    _timed("scheduler_micro", scheduler_micro.run,
           lambda rs: ";".join(f"{r['graph']}:{100*r['reduction']:.0f}%"
                               for r in rs))

    # §2.3: remat limit sweep
    _timed("remat_sweep", remat_sweep.run,
           lambda rs: ";".join(
               f"{int(100*r['fraction'])}%:{'ok' if r['ok'] else 'OOM'}"
               for r in rs))

    # symbolic comparability across architectures (plain -> bounded dims)
    _timed("symbolic_coverage", symbolic_coverage.run,
           lambda rs: ";".join(
               f"{r['arch']}:{100*r['symbolic_frac']:.0f}%"
               f"->{100*r['symbolic_frac_bounded']:.0f}%"
               for r in rs))

    # memory planner: logical peak vs planned arena vs guaranteed bound
    rows = _timed(
        "memplan", lambda: memplan_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:{r['arena_bytes'][-1]/r['peak_bytes'][-1]:.2f}"
            f"x reuse{100*r['reuse_ratio']:.0f}%"
            for r in rs))
    with open("BENCH_memplan.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(memplan_bench.format_rows(rows), file=sys.stderr)

    # shape-bucketed dispatch: bucketed vs monolithic guaranteed memory +
    # per-call dispatch overhead (hit path never re-plans — asserted inside)
    rows = _timed(
        "dispatch", lambda: dispatch_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:{r['min_bucket_over_mono']:.2f}x"
            f"@{r['dispatch_p50_ns']/1e3:.0f}us"
            for r in rs))
    with open("BENCH_dispatch.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(dispatch_bench.format_rows(rows), file=sys.stderr)

    # lowered-VM executor vs reference interpreter: per-call wall time and
    # per-op dispatch overhead on the hit path (>=2x contract asserted on
    # the dispatch microbench inside)
    rows = _timed(
        "exec", lambda: exec_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:{r['call_speedup']:.2f}x"
            f"@{r['vm_overhead_ns_per_op']:.0f}ns/op"
            for r in rs))
    with open("BENCH_exec.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(exec_bench.format_rows(rows), file=sys.stderr)

    # compile path: cold vs incremental bucket specialization, scheduler
    # hot loop, background-specialize miss-path latency (>=2x incremental
    # on >=3/4 archs + miss<=2x hit asserted inside on the full run)
    rows = _timed(
        "compile", lambda: compile_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:{r['mean_speedup']:.2f}x"
            f"@miss{r['miss_path']['miss_over_hit']:.2f}x"
            for r in rs))
    with open("BENCH_compile.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(compile_bench.format_rows(rows), file=sys.stderr)

    # symbolic control flow: rolled scan vs mechanically unrolled DAG
    # (plan size / compile time trip-count independence + per-step cost
    # <= unrolled asserted inside)
    rows = _timed(
        "loop", lambda: loop_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:{r['plan_size_ratio']:.0f}x"
            f"@{r['compile_speedup_vs_unrolled']:.1f}x"
            for r in rs))
    with open("BENCH_loop.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(loop_bench.format_rows(rows), file=sys.stderr)

    # observability: telemetry overhead contract (disabled <=2% asserted
    # inside) + plan-vs-actual timeline agreement (zero unexplained
    # allocations asserted inside at every probe env)
    rows = _timed(
        "obs", lambda: obs_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:x{r['disabled_over_base']:.3f}"
            if r["arch"] == "dispatch_chain_micro"
            else f"{r['arch']}:{r['peak_over_bound']:.3f}"
            for r in rs))
    with open("BENCH_obs.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(obs_bench.format_rows(rows), file=sys.stderr)

    # value-dependent bounded dims: measured-tight runtime accounting vs
    # the pad-to-bound counterfactual (monotone improvement as occupancy
    # drops + arena <= cap reserve asserted inside at every occupancy)
    rows = _timed(
        "bounded", lambda: bounded_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:half{r['tight_over_pad_half']:.2f}"
            f"/empty{r['tight_over_pad_empty']:.2f}"
            for r in rs))
    with open("BENCH_bounded.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(bounded_bench.format_rows(rows), file=sys.stderr)

    # per-bucket kernel-variant selection: selected plan vs the one fixed
    # Pallas configuration (>=3/4 archs improved on the small bucket +
    # every winner selected a non-default variant asserted inside)
    rows = _timed(
        "kernel", lambda: kernel_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:small{r['small_speedup']:.2f}x"
            f"/large{r['large_speedup']:.2f}x"
            for r in rs))
    with open("BENCH_kernel.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(kernel_bench.format_rows(rows), file=sys.stderr)

    # fault-tolerant serving: disabled-path <=2% contract (hard-asserted
    # inside the bench), degraded-call cost, quarantine recovery, and
    # seeded fault->record accounting
    rows = _timed(
        "resilience", lambda: resilience_bench.run(smoke=args.fast),
        lambda rs: ";".join(
            f"{r['arch']}:degr{r['degraded_over_healthy']:.2f}x"
            f"/map{r['faults_mapped_frac']:.2f}"
            for r in rs))
    with open("BENCH_resilience.json", "w") as f:
        json.dump({"rows": rows}, f, indent=2)
    print(resilience_bench.format_rows(rows), file=sys.stderr)

    # roofline readout from the dry-run artifacts (if present)
    try:
        rows = roofline.run()
        ok = [r for r in rows if "skipped" not in r]
        dom = {}
        for r in ok:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        print(f"roofline,0,cells={len(ok)};" +
              ";".join(f"{k}:{v}" for k, v in sorted(dom.items())))
    except Exception as e:
        print(f"roofline,0,unavailable({e})")


if __name__ == "__main__":
    main()
