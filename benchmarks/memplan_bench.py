"""Memory-planner benchmark: logical peak vs planned arena vs bounds.

For each benchmark arch, trace the train step with symbolic ``(b, s)``,
schedule, build the symbolic arena plan (with and without input donation),
and at several probe envs compare:

  * ``peak``   — logical free-run peak bytes (``simulate_peak``, exact);
  * ``arena``  — planned arena size (``ArenaPlan.arena_bytes``);
  * ``arena_donated`` — same with ``donate_inputs=True`` (dead input
    buffers join the reuse pool);
  * ``arena_bound_bytes``     — guaranteed arena size over the declared
    dim ranges (sound: no in-range env can need more);
  * ``guaranteed_peak_bytes`` — the interval layer's guaranteed peak.

Asserted invariants (the planner's contract):

  * reuse never loses: ``arena <= peak`` at every probe env;
  * planned reuse exists on every arch (``reuse_ratio > 0``);
  * the bound is sound: ``arena <= arena_bound_bytes`` at every probe env.

    PYTHONPATH=src python -m benchmarks.memplan_bench [--smoke] [--json F]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.memplan import build_arena_plan
from repro.core.scheduling import schedule_graph, simulate_peak, \
    simulate_peak_bound
from repro.core.symbolic import ShapeGraph, declare_dim_ranges
from repro.launch.steps import adamw_config_for, make_train_step
from repro.models import init_params
from repro.optim import init_state

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
SMOKE_ARCHS = ["llama2_1b", "musicgen_medium"]   # both input modes

BATCH_RANGE = (1, 64)
SEQ_RANGE = (16, 4096)
PROBE_ENVS = [(1, 16), (8, 512), (64, 4096)]
SMOKE_PROBE_ENVS = [(1, 16), (8, 512)]


def concretize_spec(spec, env, rng):
    """Concrete array for a (possibly symbolic) ShapeDtypeStruct.

    Shared by ``exec_bench`` and ``tests/test_lowering.py``: int dtypes
    get small token ids, float dtypes get small *positive* values (some
    leaves are optimizer second moments that the step square-roots).
    """
    import numpy as np

    from repro.core.symbolic import dim_to_expr

    shape = tuple(d if isinstance(d, int) else dim_to_expr(d).evaluate(env)
                  for d in spec.shape)
    if np.issubdtype(spec.dtype, np.integer):
        return jnp.asarray(rng.randint(1, 7, shape), spec.dtype)
    return jnp.asarray(rng.rand(*shape) * 0.02, spec.dtype)


def _step_and_specs(arch):
    """Train step + symbolic ``(b, s)`` example specs for one bench arch.

    Shared with ``dispatch_bench`` (which feeds them to ``optimize``);
    returns ``None`` for input modes the bench does not model.
    """
    cfg = dataclasses.replace(get_smoke_config(arch), scan_layers=False)
    step = make_train_step(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params, adamw_config_for(cfg))
    B, S = symbolic_dims("b, s")
    p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     opt_state)
    if cfg.input_mode == "tokens":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif cfg.input_mode == "embeddings":
        batch = {"frame_embed": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     jnp.float32),
                 "labels": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks),
                                                jnp.int32)}
    else:
        return None
    return step, (p, o, batch)


def _trace(arch):
    r = _step_and_specs(arch)
    if r is None:
        return None
    step, args = r
    g, _ = trace_to_graph(step, *args)
    return g


def run(smoke: bool = False) -> List[Dict]:
    archs = SMOKE_ARCHS if smoke else ARCHS
    probes = SMOKE_PROBE_ENVS if smoke else PROBE_ENVS
    rows = []
    for arch in archs:
        g = _trace(arch)
        if g is None:
            continue
        sg = ShapeGraph()
        declare_dim_ranges(sg, {"b": BATCH_RANGE, "s": SEQ_RANGE})
        res = schedule_graph(g, sg)
        plan = build_arena_plan(g, res.order, sg)
        plan_don = build_arena_plan(g, res.order, sg, donate_inputs=True)
        _, peak_bound = simulate_peak_bound(g, res.order, sg)

        assert plan.planned_reuse_ratio > 0, f"{arch}: no planned reuse"
        envs, peaks, arenas, arenas_don = [], [], [], []
        for (b, s) in probes:
            env = {"b": b, "s": s}
            peak = simulate_peak(g, res.order, env).peak_bytes
            arena = plan.arena_bytes(env)
            arena_d = plan_don.arena_bytes(env)
            assert arena <= peak, \
                f"{arch}@{env}: arena {arena} > logical peak {peak}"
            assert plan.arena_bound_bytes is None \
                or arena <= plan.arena_bound_bytes, \
                f"{arch}@{env}: arena {arena} exceeds its guaranteed bound"
            envs.append([b, s])
            peaks.append(peak)
            arenas.append(arena)
            arenas_don.append(arena_d)

        rows.append(dict(
            arch=arch, nodes=len(g.nodes),
            probe_envs=envs, peak_bytes=peaks, arena_bytes=arenas,
            arena_donated_bytes=arenas_don,
            arena_bound_bytes=plan.arena_bound_bytes,
            guaranteed_peak_bytes=peak_bound,
            slots=plan.n_slots,
            reuse_ratio=round(plan.planned_reuse_ratio, 4),
            provable_reuses=plan.n_provable_reuses,
            checked_reuses=plan.n_checked_reuses,
            donated_reuses=plan_don.n_donated_reuses,
        ))
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        out.append(f"{r['arch']:18s} slots={r['slots']:4d} "
                   f"reuse={100*r['reuse_ratio']:.0f}% "
                   f"(prov={r['provable_reuses']}, chk={r['checked_reuses']}, "
                   f"don={r['donated_reuses']})")
        for (b, s), peak, ar, ard in zip(r["probe_envs"], r["peak_bytes"],
                                         r["arena_bytes"],
                                         r["arena_donated_bytes"]):
            out.append(f"    ({b:2d},{s:4d}): peak={peak/2**20:9.1f}MiB "
                       f"arena={ar/2**20:9.1f}MiB ({ar/peak:5.1%}) "
                       f"donated={ard/2**20:9.1f}MiB")
        bound = r["arena_bound_bytes"]
        gp = r["guaranteed_peak_bytes"]
        out.append(f"    arena<= {bound/2**20:.0f}MiB guaranteed, "
                   f"peak<= {gp/2**20:.0f}MiB guaranteed")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two archs, two probe envs (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
