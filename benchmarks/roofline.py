"""Roofline analysis (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
    compute term    = FLOPs             / (chips × peak MXU FLOP/s)
    memory term     = HBM bytes         / (chips × HBM bytes/s)
    collective term = collective bytes  / (chips × ICI bytes/s per link)

The machine constants come from :mod:`repro.kernels.hw_model` — the same
``HardwareModel`` the kernel-variant cost model prices Pallas block
configurations with, so a kernel the selector calls compute-bound can
never look memory-bound in this table.

Two data sources, auto-selected:

* **dry-run artifacts** (``experiments/dryrun/*.json``): trip-count-scaled
  HLO analysis of the per-device partitioned module, when a prior
  dry-run produced them;
* **analytic fallback** (no artifacts): per-chip terms estimated straight
  from the architecture configs — weight/activation/KV-cache traffic and
  6ND (train) / 2ND (inference) FLOPs — so the benchmark always runs
  against the current package layout.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) global, /chips.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES, get_config
from repro.kernels.hw_model import DEFAULT_HW

PEAK_FLOPS = DEFAULT_HW.peak_flops   # bf16 / chip
HBM_BW = DEFAULT_HW.hbm_bw           # bytes/s / chip
LINK_BW = DEFAULT_HW.link_bw         # bytes/s / link (ICI)

_BYTES_PER_PARAM = 2                 # bf16 weights
_ANALYTIC_CHIPS = 256
_ANALYTIC_ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.n_experts > 0)
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * spec["global_batch"]


def _analytic_bytes(cfg, spec) -> Dict[str, float]:
    """Per-step global HBM + collective traffic estimated from the config.

    Deliberately coarse — the point is correct dominant-term
    classification (train compute-bound, decode memory-bound), not
    byte-exact accounting: weights stream once per step (three times
    under training: forward, backward, optimizer), activations pay a
    dozen round-trips per layer, decode re-reads the KV cache every
    token, and training all-reduces gradients (~2× payload on a ring).
    """
    n_params = cfg.param_count(active_only=cfg.n_experts > 0)
    param_b = n_params * _BYTES_PER_PARAM
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    kind = spec["kind"]
    batch, seq = spec["global_batch"], spec["seq_len"]
    if kind == "train":
        tokens = batch * seq
        act_b = 12.0 * tokens * cfg.d_model * cfg.n_layers * _BYTES_PER_PARAM
        return dict(hbm=3.0 * param_b + act_b, coll=2.0 * param_b)
    if kind == "prefill":
        tokens = batch * seq
        act_b = 12.0 * tokens * cfg.d_model * cfg.n_layers * _BYTES_PER_PARAM
        return dict(hbm=param_b + act_b, coll=0.0)
    # decode: one token per sequence, full KV cache re-read per step
    kv_b = (2.0 * batch * seq * cfg.n_layers * cfg.n_kv_heads * head_dim
            * _BYTES_PER_PARAM)
    act_b = 12.0 * batch * cfg.d_model * cfg.n_layers * _BYTES_PER_PARAM
    return dict(hbm=param_b + kv_b + act_b, coll=0.0)


def _classify(flops: float, hbm: float, coll: float, chips: int,
              mf: float) -> Dict:
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = coll / LINK_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                   key=lambda kv: kv[1])[0]
    return dict(
        compute_s=t_c, memory_s=t_m, collective_s=t_n, dominant=dominant,
        model_flops_per_chip=mf,
        useful_flop_ratio=(mf / flops) if flops else 0.0,
        roofline_fraction=(t_c / max(t_c, t_m, t_n))
        if (t_c or t_m or t_n) else 0.0,
    )


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    sc = rec.get("scaled", {})
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    row = _classify(sc.get("flops", 0.0), sc.get("hbm_bytes", 0.0),
                    sc.get("collective_bytes", 0.0), chips, mf)
    mem = rec.get("memory", {})
    row.update(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        mem_per_device_gib=mem.get("total_per_device_bytes", 0) / 2**30,
        fits_hbm=mem.get("total_per_device_bytes", 0) <= 16 * 2**30)
    return row


def analytic_record(arch: str, shape_name: str,
                    chips: int = _ANALYTIC_CHIPS) -> Dict:
    """One roofline row estimated from the config registry alone."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mf = model_flops(arch, shape_name) / chips
    traffic = _analytic_bytes(cfg, spec)
    row = _classify(mf, traffic["hbm"] / chips, traffic["coll"] / chips,
                    chips, mf)
    per_dev = (cfg.param_count(active_only=False) * _BYTES_PER_PARAM) / chips
    row.update(arch=arch, shape=shape_name, mesh=f"analytic/{chips}",
               mem_per_device_gib=per_dev / 2**30,
               fits_hbm=per_dev <= 16 * 2**30)
    return row


def analytic_rows(archs: Optional[List[str]] = None,
                  shapes: Optional[List[str]] = None) -> List[Dict]:
    archs = archs if archs is not None else [
        a for a in _ANALYTIC_ARCHS if a in ARCHS]
    shapes = shapes if shapes is not None else list(SHAPES)
    return [analytic_record(a, s) for a in archs for s in shapes]


def load_all(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    """Rows from dry-run artifacts; the analytic estimate when there are
    none (a fresh checkout runs the benchmark without any prior step)."""
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        row = analyze_record(rec)
        if row is None:
            rows.append(dict(arch=rec.get("arch"), shape=rec.get("shape"),
                             mesh=rec.get("mesh"),
                             skipped=rec.get("skip_reason",
                                             rec.get("error", "?"))[:60]))
        else:
            rows.append(row)
    if not rows:
        rows = analytic_rows()
    return rows


def to_markdown(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful-FLOP ratio | mem/dev GiB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped: {r['skipped']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {r['mem_per_device_gib']:.2f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def run(dryrun_dir: str = "experiments/dryrun"):
    return load_all(dryrun_dir)


if __name__ == "__main__":
    rows = load_all()
    print(to_markdown(rows))
    out = "experiments/roofline.md"
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write(to_markdown(rows) + "\n")
    print(f"\nwritten {out}")
