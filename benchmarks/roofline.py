"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs            / (chips × 197 TFLOP/s bf16)
    memory term     = HLO_bytes (scaled)   / (chips × 819 GB/s HBM)
    collective term = collective_bytes     / (chips × 50 GB/s ICI/link)

FLOPs / bytes / collective bytes come from the trip-count-scaled HLO
analysis of the *per-device* partitioned module (see
repro/launch/hlo_analysis.py), so terms are already per-chip.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) global, /chips.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.n_experts > 0)
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * spec["global_batch"]


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    sc = rec.get("scaled", {})
    flops = sc.get("flops", 0.0)
    hbm = sc.get("hbm_bytes", 0.0)
    coll = sc.get("collective_bytes", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = coll / LINK_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                   key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    mem = rec.get("memory", {})
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=t_c, memory_s=t_m, collective_s=t_n,
        dominant=dominant,
        model_flops_per_chip=mf,
        useful_flop_ratio=(mf / flops) if flops else 0.0,
        mem_per_device_gib=mem.get("total_per_device_bytes", 0) / 2**30,
        fits_hbm=mem.get("total_per_device_bytes", 0) <= 16 * 2**30,
        # roofline fraction: how close the compute term is to being the
        # step's runtime if the dominant term set the pace
        roofline_fraction=(t_c / max(t_c, t_m, t_n)) if (t_c or t_m or t_n) else 0.0,
    )


def load_all(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        row = analyze_record(rec)
        if row is None:
            rows.append(dict(arch=rec.get("arch"), shape=rec.get("shape"),
                             mesh=rec.get("mesh"),
                             skipped=rec.get("skip_reason",
                                             rec.get("error", "?"))[:60]))
        else:
            rows.append(row)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful-FLOP ratio | mem/dev GiB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped: {r['skipped']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {r['mem_per_device_gib']:.2f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def run(dryrun_dir: str = "experiments/dryrun"):
    return load_all(dryrun_dir)


if __name__ == "__main__":
    rows = load_all()
    print(to_markdown(rows))
    out = "experiments/roofline.md"
    with open(out, "w") as f:
        f.write(to_markdown(rows) + "\n")
    print(f"\nwritten {out}")
