"""Observability benchmark: overhead contract + plan-vs-actual agreement.

Two measured surfaces:

* **overhead contract** — on the dispatch-chain microbench (256 tiny ops,
  the executor-structure-dominated worst case), finely interleaved
  single-call wall samples with telemetry never enabled vs enabled vs
  re-disabled (tracked as ``disabled_over_base`` /
  ``enabled_over_disabled``).  The hard <=2% contract is asserted on a
  deterministic decomposition — the isolated cost of the disabled-path
  telemetry check against the measured call time — because on shared
  runners ambient noise between two runs of the *identical* code path
  exceeds 2%, so an A/B wall assertion at that tolerance measures the
  machine, not the telemetry.  Also asserted: the Chrome-trace export of
  the compile is valid JSON with properly nested spans;
* **plan-vs-actual agreement** — for each benchmark arch, at each probe
  env, the reconstructed per-instruction memory timeline
  (``fn.memory_timeline``) against the compile-time plan.  Asserted: the
  actual arena stays under the plan's guaranteed ``arena_bound_bytes``
  and **every** allocation is explained by a planned liveness interval
  (zero unexplained) — the paper's "the plan is the truth" gate.

``peak_over_bound`` (actual arena / guaranteed bound, worst probe env)
is the deterministic regression metric; ``enabled_over_disabled`` tracks
telemetry cost.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--json F]
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import optimize, symbolic_dims
from repro.core.obs import chrome_trace_json

from benchmarks.exec_bench import CHAIN_OPS
from benchmarks.memplan_bench import (ARCHS, BATCH_RANGE, PROBE_ENVS,
                                      SEQ_RANGE, SMOKE_ARCHS,
                                      SMOKE_PROBE_ENVS, _step_and_specs)

ROUNDS = 100                      # interleaved single-call samples per label
OVERHEAD_TOL = 1.02               # the <=2% contract


def _validate_trace(text: str) -> int:
    """Parse a Chrome-trace export; return the event count.

    Checks the shape contract viewers rely on: a ``traceEvents`` list,
    every complete event carrying ts/dur/pid/tid, and child spans nested
    inside their parent's time window."""
    data = json.loads(text)
    events = data["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X"]
    assert spans, "trace export has no complete events"
    for e in spans:
        assert {"name", "ts", "dur", "pid", "tid"} <= set(e), e
        assert e["dur"] >= 0, e
    return len(events)


def _chain_overhead() -> Dict:
    """Telemetry cost on the executor-overhead-dominated chain."""
    n, = symbolic_dims("n")

    def chain(x):
        for _ in range(CHAIN_OPS // 2):
            x = x * 1.0000001 + 0.5
        return x

    fn = optimize(chain, jax.ShapeDtypeStruct((n,), jnp.float32),
                  dynamic_dims={"n": (8, 4096)})
    x = jnp.arange(64, dtype=jnp.float32)
    for _ in range(10):
        fn(x)                                    # warm: resolve + caches

    def sample() -> float:
        t0 = time.perf_counter()
        fn(x)
        return time.perf_counter() - t0

    # finely interleaved single-call samples, one per label per round:
    # "base" and "dis" run the *identical* code path with telemetry off
    # (their ratio checks that disabling telemetry leaves no residue),
    # "en" runs with a live ring.  The estimator is min over each label's
    # samples — the standard way to read the true cost on a machine with
    # additive noise (CFS throttling, noisy neighbors): min discards the
    # contaminated samples.  Two aliasing traps this layout dodges: the
    # label->position mapping rotates every round, because periodic
    # backend costs (batched deallocation) can align to a fixed position
    # in a rigid round and bill one label systematically; and the
    # collector is paused so the toggling garbage cannot bill its
    # collection to whichever sample the cycle lands in (timeit's trick).
    import gc

    sinks = {"base": [], "dis": [], "en": []}
    labels = ["base", "dis", "en"]
    ring_len = 0
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for r in range(ROUNDS):
            k = r % 3
            for label in labels[k:] + labels[:k]:
                if label == "en":
                    fn.enable_telemetry(capacity=256)
                sinks[label].append(sample())
                if label == "en":
                    ring_len = max(ring_len, len(fn.telemetry.ring))
                    fn.disable_telemetry()
    finally:
        if gc_was_enabled:
            gc.enable()
    base_s, dis_s, en_s = sinks["base"], sinks["dis"], sinks["en"]
    assert ring_len > 0, "enabled telemetry recorded no calls"
    base_us = min(base_s) * 1e6
    disabled_us = min(dis_s) * 1e6
    enabled_us = min(en_s) * 1e6

    # the A/B wall ratios above are *tracked* (BENCH_obs.json, regress
    # guard), not hard-asserted: on shared runners the ambient noise
    # between two runs of the IDENTICAL code path ("base" vs "dis")
    # routinely exceeds 2%, so a 2% A/B assertion measures the machine,
    # not the telemetry.  The hard <=2% contract is asserted on a
    # deterministic decomposition instead: the disabled hot path's only
    # added work is the `self._telemetry is None` check — time exactly
    # that sequence in isolation (tens of ns, stable to measure because
    # 10^5 iterations amortize every noise source) and require it to be
    # under 2% of the measured call itself.  It lands near 0.001%, so
    # the margin is ~1000x and the assertion cannot flake.
    n_iter = 100_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        tel = fn._telemetry
        if tel is not None:
            raise AssertionError("telemetry unexpectedly enabled")
    check_ns = (time.perf_counter() - t0) / n_iter * 1e9
    check_frac = check_ns / (disabled_us * 1e3)
    assert check_frac <= OVERHEAD_TOL - 1, (
        f"disabled-telemetry check costs {check_ns:.0f}ns = "
        f"{check_frac * 100:.3f}% of a {disabled_us:.0f}us call "
        f"(contract: <=2%)")

    ratio = disabled_us / base_us
    en_ratio = enabled_us / base_us
    n_events = _validate_trace(chrome_trace_json(fn.trace))
    return dict(
        arch="dispatch_chain_micro",
        n_ops=CHAIN_OPS,
        base_call_us=round(base_us, 1),
        enabled_call_us=round(enabled_us, 1),
        disabled_call_us=round(disabled_us, 1),
        disabled_check_ns=round(check_ns, 1),
        disabled_check_frac=round(check_frac, 6),
        disabled_over_base=round(ratio, 4),
        enabled_over_disabled=round(en_ratio, 4),
        ring_records=ring_len,
        trace_events=n_events,
    )


def _arch_agreement(arch: str, probes) -> Dict:
    """Plan-vs-actual timeline agreement for one arch at every probe."""
    r = _step_and_specs(arch)
    if r is None:
        return None
    step, specs = r
    fn = optimize(step, *specs,
                  dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE})
    _validate_trace(chrome_trace_json(fn.trace))

    envs, actuals, predicted = [], [], []
    ratios: Dict = {}
    unexplained_total = 0
    for (b, s) in probes:
        env = {"b": b, "s": s}
        diff = fn.memory_timeline(env)
        assert diff.within_bound, (
            f"{arch}@{env}: actual arena {diff.actual.arena_bytes} over "
            f"guaranteed bound {diff.arena_bound_bytes}")
        assert not diff.unexplained, (
            f"{arch}@{env}: {len(diff.unexplained)} unexplained "
            f"allocations, first: {diff.unexplained[0]}")
        envs.append([b, s])
        actuals.append(diff.actual.arena_bytes)
        predicted.append(diff.predicted_peak_device)
        if diff.arena_bound_bytes:
            ratios[(b, s)] = (diff.actual.arena_bytes
                              / diff.arena_bound_bytes)
        unexplained_total += len(diff.unexplained)
    # the regression metric is anchored at the probe env both smoke and
    # full runs share, so fresh-smoke vs committed-full comparisons are
    # apples to apples (the soundness assertion above already covered
    # every probed env, including the largest)
    anchor = ratios.get((8, 512), max(ratios.values()) if ratios else None)
    return dict(
        arch=arch,
        probe_envs=envs,
        actual_arena_bytes=actuals,
        predicted_peak_bytes=predicted,
        arena_bound_bytes=fn.arena_bound_bytes,
        peak_over_bound=round(anchor, 4) if anchor is not None else None,
        unexplained_total=unexplained_total,
        timeline_points=len(fn.memory_timeline(
            {"b": probes[0][0], "s": probes[0][1]}).actual.points),
    )


def run(smoke: bool = False) -> List[Dict]:
    archs = SMOKE_ARCHS if smoke else ARCHS
    probes = SMOKE_PROBE_ENVS if smoke else PROBE_ENVS
    rows = [_chain_overhead()]
    for arch in archs:
        row = _arch_agreement(arch, probes)
        if row is not None:
            rows.append(row)
    for r in rows:
        r["smoke"] = smoke   # bench_regress doubles tolerance for smoke rows
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        if r["arch"] == "dispatch_chain_micro":
            out.append(
                f"{r['arch']:18s} base={r['base_call_us']:7.1f}us "
                f"enabled={r['enabled_call_us']:7.1f}us "
                f"disabled={r['disabled_call_us']:7.1f}us "
                f"check={r['disabled_check_ns']:.0f}ns "
                f"({100 * r['disabled_check_frac']:.4f}% of call, "
                f"contract <=2%) trace={r['trace_events']} events")
            continue
        out.append(
            f"{r['arch']:18s} peak/bound={r['peak_over_bound']:.4f} "
            f"unexplained={r['unexplained_total']} "
            f"({len(r['probe_envs'])} envs, "
            f"{r['timeline_points']} timeline points)")
    return "\n".join(out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two archs, two probe envs (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
