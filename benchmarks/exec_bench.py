"""Executor benchmark: per-call wall time and per-op dispatch overhead,
lowered ProgramVM vs the reference op-by-op interpreter.

Two measurement tiers, both on the hit path (env resolved and cached —
the steady state of training and of bucketed serving):

* a **dispatch microbench** — a long chain of tiny elementwise ops, so
  per-op executor overhead dominates the math and the
  ``(call - floor) / n_ops`` subtraction is stable.  ``floor`` replays
  the identical (primitive, inputs, params) sequence with no executor
  around it;
* the **benchmark archs** — real train steps, where per-call wall time
  is the serving-relevant number (the big binds dominate, so the
  derived per-op overhead is reported but inherently noisier).

Asserted invariants (the lowering contract):

  * microbench: the VM's per-op dispatch overhead is >= 2x below the
    reference interpreter's (the hard, stable contract);
  * every arch: the VM call is not clearly slower than the reference
    call (25% sanity bound — arch calls are math-dominated and jittery
    on shared runners).

    PYTHONPATH=src python -m benchmarks.exec_bench [--smoke] [--json F]
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np
from jax import tree_util

from repro.core import optimize
from repro.core.executor.interpreter import PlanInterpreter
from repro.core.lowering.program import OP_BIND_ARG, OP_COMPUTE

from benchmarks.memplan_bench import _step_and_specs, concretize_spec

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]
SMOKE_ARCHS = ["llama2_1b", "musicgen_medium"]   # both input modes

DIM_RANGES = {"b": (1, 8), "s": (8, 128)}
ENV = {"b": 1, "s": 16}
N_CALLS = 12


def _record_bind_sequence(program, flat_args, env) -> List:
    """One recorded pass over the fast stream: the exact (prim, inputs,
    params) triples a call binds, with executor structure stripped."""
    resolved = program.resolve(env)
    storage = [None] * program.n_regs
    seq = []
    for inst in program.fast_instructions:
        op = inst.op
        if op == OP_COMPUTE:
            ins = [storage[r] for r in inst.in_regs]
            p = resolved.params[inst.cidx]
            if inst.dim_as_value:
                outs = [jnp.asarray(p["dim"], jnp.int32)]
            elif inst.multi:
                outs = list(inst.prim.bind(*ins, **p))
            else:
                outs = [inst.prim.bind(*ins, **p)]
            seq.append((inst.prim, ins, p, inst.multi, inst.dim_as_value))
            for oi, r in inst.store:
                storage[r] = outs[oi] if inst.multi else outs[0]
        elif op == OP_BIND_ARG:
            storage[inst.reg] = (flat_args[inst.index]
                                 if inst.index >= 0 else inst.const)
    return seq


def _best_wall_us(fn, n: int = N_CALLS) -> float:
    """Best-of-n wall time: the least-noise estimate of the true cost."""
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _measure(vm, ref, program, flat, env, n_calls=N_CALLS) -> Dict:
    """Warm both executors, record the bind floor, time everything."""
    vm.run(flat)                                 # warm: resolve + caches
    ref.run(flat)
    seq = _record_bind_sequence(program, flat, env)

    def bind_floor():
        for prim, ins, p, multi, dimv in seq:
            if dimv:
                jnp.asarray(p["dim"], jnp.int32)
            else:
                prim.bind(*ins, **p)

    floor_us = _best_wall_us(bind_floor, n_calls)
    vm_us = _best_wall_us(lambda: vm.run(flat), n_calls)
    ref_us = _best_wall_us(lambda: ref.run(flat), n_calls)
    n_ops = len(seq)
    vm_over = max(0.0, (vm_us - floor_us)) * 1e3 / n_ops
    ref_over = max(0.0, (ref_us - floor_us)) * 1e3 / n_ops
    return dict(
        n_ops=n_ops,
        floor_call_us=round(floor_us, 1),
        vm_call_us=round(vm_us, 1),
        ref_call_us=round(ref_us, 1),
        vm_overhead_ns_per_op=round(vm_over, 1),
        ref_overhead_ns_per_op=round(ref_over, 1),
        # None: the VM ran at (or under) the bind floor — its overhead is
        # below measurement noise, so no finite ratio exists
        overhead_ratio=round(ref_over / vm_over, 2) if vm_over > 0 else None,
        call_speedup=round(ref_us / vm_us, 3),
    )


CHAIN_OPS = 256


def _chain_micro() -> Dict:
    """Per-op dispatch overhead isolated: a chain of tiny elementwise ops
    where executor structure, not math, is the cost."""
    import jax

    from repro.core import symbolic_dims

    n, = symbolic_dims("n")

    def chain(x):
        for _ in range(CHAIN_OPS // 2):
            x = x * 1.0000001 + 0.5
        return x

    fn = optimize(chain, jax.ShapeDtypeStruct((n,), jnp.float32),
                  dynamic_dims={"n": (8, 4096)})
    ref = PlanInterpreter(fn.plan)
    flat = [jnp.arange(64, dtype=jnp.float32)]
    row = _measure(fn.interp, ref, fn.program, flat, {"n": 64}, n_calls=30)
    row["arch"] = "dispatch_chain_micro"
    row["n_instructions"] = fn.program.n_instructions
    assert row["vm_overhead_ns_per_op"] * 2 <= row["ref_overhead_ns_per_op"], (
        f"VM per-op dispatch overhead {row['vm_overhead_ns_per_op']:.0f}ns "
        f"is not >=2x below the reference's "
        f"{row['ref_overhead_ns_per_op']:.0f}ns")
    return row


def run(smoke: bool = False) -> List[Dict]:
    archs = SMOKE_ARCHS if smoke else ARCHS
    rows = [_chain_micro()]
    for r in rows:
        r["smoke"] = smoke   # bench_regress doubles tolerance for smoke rows
    for arch in archs:
        r = _step_and_specs(arch)
        if r is None:
            continue
        step, args = r
        fn = optimize(step, *args, dynamic_dims=DIM_RANGES)
        ref = PlanInterpreter(fn.plan)           # same plan, both executors
        flat_specs, _ = tree_util.tree_flatten((args, {}))
        rng = np.random.RandomState(0)
        flat = [concretize_spec(s, ENV, rng) for s in flat_specs]

        row = _measure(fn.interp, ref, fn.program, flat, ENV)
        row["arch"] = arch
        row["n_instructions"] = fn.program.n_instructions
        # loose wall-clock sanity bound only: the hard >=2x contract is
        # asserted on the microbench above, where the measurement is
        # stable; arch calls are dominated by the math, so a shared CI
        # runner can jitter them by far more than the VM's win
        assert row["vm_call_us"] <= row["ref_call_us"] * 1.25, (
            f"{arch}: VM call {row['vm_call_us']:.0f}us clearly slower "
            f"than reference {row['ref_call_us']:.0f}us")
        row["smoke"] = smoke
        rows.append(row)
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        ratio = r["overhead_ratio"]
        tail = "below floor" if ratio is None else f"{ratio:.1f}x"
        out.append(
            f"{r['arch']:18s} {r['n_ops']:4d} ops  "
            f"call vm={r['vm_call_us']:8.1f}us ref={r['ref_call_us']:8.1f}us "
            f"(floor {r['floor_call_us']:8.1f}us)  "
            f"overhead/op vm={r['vm_overhead_ns_per_op']:6.0f}ns "
            f"ref={r['ref_overhead_ns_per_op']:6.0f}ns ({tail})")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two archs (CI)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
