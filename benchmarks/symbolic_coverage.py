"""How often can memory impacts be compared purely symbolically?

The paper's method stands on SymbolicExpr comparability; this benchmark
traces several architecture train steps with symbolic (batch, seq) and
reports the fraction of ReadySet decisions resolved symbolically vs via
the lifetime tie-break — once with *no* declared dim ranges (the seed
behaviour) and once with bounded dynamic shapes declared
(``1 <= batch <= 64``, ``16 <= seq <= 4096``), which lets the interval
fallback resolve comparisons the polynomial ordering alone cannot.

With ranges declared it also reports the compile-time guaranteed
worst-case peak (``simulate_peak_bound``) and verifies that the observed
simulated peak never exceeds it for envs inside the ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.remat.planner import build_plan
from repro.core.scheduling import schedule_graph, simulate_peak, \
    simulate_peak_bound
from repro.core.symbolic import ShapeGraph, declare_dim_ranges
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import init_state
from repro.launch.steps import adamw_config_for


ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]

BATCH_RANGE = (1, 64)
SEQ_RANGE = (16, 4096)
# envs (within the declared ranges) at which the guaranteed bound is checked
PROBE_ENVS = [(1, 16), (8, 512), (64, 4096)]


def run() -> List[Dict]:
    rows = []
    for arch in ARCHS:
        cfg = dataclasses.replace(get_smoke_config(arch), scan_layers=False)
        step = make_train_step(cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_state(params, adamw_config_for(cfg))
        bname, sname = f"b_{arch[:3]}", f"s_{arch[:3]}"
        B, S = symbolic_dims(f"{bname}, {sname}")
        p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         opt_state)
        if cfg.input_mode == "tokens":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        elif cfg.input_mode == "embeddings":
            batch = {"frame_embed": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                         jnp.float32),
                     "labels": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks),
                                                    jnp.int32)}
        else:
            continue
        g, _ = trace_to_graph(step, p, o, batch)

        # before: polynomial comparison only (no declared ranges)
        res_before = schedule_graph(g, ShapeGraph())

        # after: bounded dynamic shapes declared
        sg = ShapeGraph()
        declare_dim_ranges(sg, {bname: BATCH_RANGE, sname: SEQ_RANGE})
        res_after = schedule_graph(g, sg)
        plan = build_plan(g, res_after, sg)

        # compile-time guaranteed peak vs observed simulated peak
        _, bound = simulate_peak_bound(g, res_after.order, sg)
        worst_observed = 0
        for b, s in PROBE_ENVS:
            tl = simulate_peak(g, res_after.order, {bname: b, sname: s})
            worst_observed = max(worst_observed, tl.peak_bytes)
            assert bound is None or tl.peak_bytes <= bound, \
                f"{arch}: simulated peak {tl.peak_bytes} exceeds bound {bound}"

        rows.append(dict(
            arch=arch, nodes=len(g.nodes),
            symbolic_frac=res_before.decision_symbolic_fraction,
            symbolic_frac_bounded=res_after.decision_symbolic_fraction,
            candidates=plan.n_candidates,
            recomputable=plan.n_recomputable,
            static_regen=plan.n_static_regen,
            peak_bound=bound,
            peak_observed=worst_observed,
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        bound = "unbounded" if r["peak_bound"] is None else \
            f"{r['peak_bound'] / 2**20:.0f}MiB"
        print(f"{r['arch']:18s} nodes={r['nodes']:5d} "
              f"symbolic-decisions={100*r['symbolic_frac']:5.1f}% "
              f"-> bounded={100*r['symbolic_frac_bounded']:5.1f}% "
              f"remat-candidates={r['candidates']:4d} "
              f"recomputable={r['recomputable']:4d} "
              f"static-regen={r['static_regen']:4d} "
              f"peak<= {bound} (observed {r['peak_observed'] / 2**20:.0f}MiB)")
