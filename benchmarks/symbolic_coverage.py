"""How often can memory impacts be compared purely symbolically?

The paper's method stands on SymbolicExpr comparability; this benchmark
traces several architecture train steps with symbolic (batch, seq) and
reports the fraction of ReadySet decisions resolved symbolically vs via
the lifetime tie-break, plus remat-candidate statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.remat.planner import build_plan
from repro.core.scheduling import schedule_graph
from repro.core.symbolic import ShapeGraph
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import init_state
from repro.launch.steps import adamw_config_for


ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]


def run() -> List[Dict]:
    rows = []
    for arch in ARCHS:
        cfg = dataclasses.replace(get_smoke_config(arch), scan_layers=False)
        step = make_train_step(cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_state(params, adamw_config_for(cfg))
        B, S = symbolic_dims(f"b_{arch[:3]}, s_{arch[:3]}")
        p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         opt_state)
        if cfg.input_mode == "tokens":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        elif cfg.input_mode == "embeddings":
            batch = {"frame_embed": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                         jnp.float32),
                     "labels": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks),
                                                    jnp.int32)}
        else:
            continue
        g, _ = trace_to_graph(step, p, o, batch)
        res = schedule_graph(g, ShapeGraph())
        plan = build_plan(g, res, ShapeGraph())
        rows.append(dict(
            arch=arch, nodes=len(g.nodes),
            symbolic_frac=res.decision_symbolic_fraction,
            candidates=plan.n_candidates,
            recomputable=plan.n_recomputable,
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['arch']:18s} nodes={r['nodes']:5d} "
              f"symbolic-decisions={100*r['symbolic_frac']:5.1f}% "
              f"remat-candidates={r['candidates']:4d} "
              f"recomputable={r['recomputable']:4d}")
