"""Paper Table 1 analogue: Llama fine-tuning on variable-length batches.

Three systems, as in §3 of the paper:
  * ``disc-dynamic``  — dynamic shapes, NO memory optimization (BladeDISC);
  * ``disc-static``   — power-of-two padded buckets, memory optimization
                        with *exact* shapes, recompile per new bucket
                        (BladeDISC static);
  * ``disc++``        — symbolic-shape scheduling + runtime remat, one
                        trace, no padding (BladeDISC++).

Reported per system: tokens/s (useful tokens), exact peak device bytes,
recompilations, padded-token fraction.  The memory-limit sweep reproduces
the paper's OOM row: at the limit set by disc++'s batch-14 peak, the
unoptimized dynamic system OOMs on larger batches while disc++ keeps
fitting via runtime rematerialization.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dims
from repro.core.executor.memory import MemoryLimitExceeded
from repro.data import DataPipeline, PipelineConfig
from repro.launch.steps import adamw_config_for, make_train_step
from repro.models import init_params
from repro.optim import init_state


def _specs_symbolic(cfg, params, opt_state):
    B, S = symbolic_dims("b, s")
    p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    return p, o, batch


def _specs_concrete(cfg, params, opt_state, b, s):
    p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "mask": jax.ShapeDtypeStruct((b, s), jnp.float32)}
    return p, o, batch


def _runner_for(system: str, runners: Dict, out: Dict, step, cfg, params,
                opt_state, b: int, s: int, memory_limit):
    if system == "disc++":
        if "sym" not in runners:
            runners["sym"] = optimize(
                step, *_specs_symbolic(cfg, params, opt_state),
                memory_limit=memory_limit)
            out["recompiles"] += 1  # the single symbolic compile
        return runners["sym"]
    if system == "disc-static":
        if (b, s) not in runners:
            runners[(b, s)] = optimize(
                step, *_specs_concrete(cfg, params, opt_state, b, s),
                memory_limit=memory_limit)
            out["recompiles"] += 1
        return runners[(b, s)]
    if "base" not in runners:  # disc-dynamic: no scheduling, no remat
        runners["base"] = optimize(
            step, *_specs_symbolic(cfg, params, opt_state),
            enable_scheduling=False, enable_remat=False,
            memory_limit=memory_limit)
        out["recompiles"] += 1
    return runners["base"]


def run_system(system: str, cfg, *, batch_size: int, steps: int,
               memory_limit: Optional[int] = None,
               seed: int = 0, warmup: bool = True) -> Dict[str, Any]:
    cfg = dataclasses.replace(cfg, scan_layers=False)
    step = make_train_step(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_state(params, adamw_config_for(cfg))
    mode = "bucketed" if system == "disc-static" else "dynamic"
    pipe = DataPipeline(PipelineConfig(vocab=cfg.vocab, batch_size=batch_size,
                                       seed=seed, mode=mode,
                                       min_tokens=16, max_tokens=128))
    out: Dict[str, Any] = dict(system=system, batch=batch_size, peak=0,
                               recompiles=0, useful_tokens=0, total_tokens=0,
                               losses=[], oom=False)
    runners: Dict[Any, Any] = {}
    try:
        if warmup:
            # prime tracing + JAX's eager per-op compile caches over the
            # SAME batch sequence, then measure a steady-state epoch
            saved = pipe.state()
            for _ in range(steps):
                raw = pipe.next_batch()
                b, s = raw["tokens"].shape
                batch = {k: jnp.asarray(raw[k])
                         for k in ("tokens", "labels", "mask")}
                fn = _runner_for(system, runners, out, step, cfg, params,
                                 opt_state, b, s, memory_limit)
                fn(params, opt_state, batch)
            pipe.restore(saved)
        t0 = time.time()
        for _ in range(steps):
            raw = pipe.next_batch()
            b, s = raw["tokens"].shape
            batch = {k: jnp.asarray(raw[k]) for k in ("tokens", "labels", "mask")}
            fn = _runner_for(system, runners, out, step, cfg, params,
                             opt_state, b, s, memory_limit)
            loss, params, opt_state = fn(params, opt_state, batch)
            rep = fn.last_report
            out["peak"] = max(out["peak"], rep.stats.device_peak)
            out["losses"].append(float(loss))
            out["useful_tokens"] += int(raw["mask"].sum())
            out["total_tokens"] += int(raw["tokens"].size)
    except MemoryLimitExceeded:
        out["oom"] = True
        t0 = out.get("_t0", time.time())
    out["wall_s"] = time.time() - t0
    out["tokens_per_s"] = out["useful_tokens"] / max(out["wall_s"], 1e-9)
    out["pad_frac"] = 1.0 - out["useful_tokens"] / max(out["total_tokens"], 1)
    return out


def run(steps: int = 12, batches=(6, 8, 10)) -> List[Dict[str, Any]]:
    cfg = get_smoke_config("llama2_1b")
    rows: List[Dict[str, Any]] = []
    # memory-free pass to establish peaks
    for system in ("disc-dynamic", "disc-static", "disc++"):
        rows.append(run_system(system, cfg, batch_size=batches[0], steps=steps))
    # the paper's OOM experiment: cap at disc++'s smallest-batch peak (+5%)
    limit = int(next(r["peak"] for r in rows if r["system"] == "disc++") * 1.05)
    for b in batches[1:]:
        for system in ("disc-dynamic", "disc++"):
            rows.append(run_system(system, cfg, batch_size=b, steps=steps,
                                   memory_limit=limit))
    return rows


def format_rows(rows) -> str:
    hdr = (f"{'system':14s} {'batch':>5s} {'tok/s':>8s} {'peak MiB':>9s} "
           f"{'recompiles':>10s} {'pad%':>6s} {'status':>7s}")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['system']:14s} {r['batch']:5d} {r['tokens_per_s']:8.0f} "
            f"{r['peak']/2**20:9.1f} {r['recompiles']:10d} "
            f"{100*r['pad_frac']:6.1f} {'OOM' if r['oom'] else 'ok':>7s}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_rows(run()))
