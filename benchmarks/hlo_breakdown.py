"""Per-instruction HBM/collective breakdown of a dry-run cell (the
profiling tool of the §Perf loop — our 'profile' is the lowered module).

    PYTHONPATH=src python -m benchmarks.hlo_breakdown --arch deepseek-v3-671b \
        --shape train_4k [--multi] [--top 25]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from typing import List, Tuple

import jax

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step


def compile_cell(arch: str, shape: str, multi_pod: bool = False,
                 grad_accum: int = 8):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh)
    kind, specs = input_specs(cfg, shape)

    def shard(tree, spec_fn):
        return jax.tree.map(
            lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
            tree, rules.named(spec_fn(tree)))

    with mesh:
        if kind == "train":
            fn = make_train_step(cfg, grad_accum=grad_accum)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["opt_state"], rules.params_pspecs),
                    shard(specs["batch"], rules.batch_specs))
            jfn = jax.jit(fn, donate_argnums=(0, 1))
        elif kind == "prefill":
            fn = make_prefill_step(cfg)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["batch"], rules.batch_specs))
            jfn = jax.jit(fn)
        else:
            fn = make_serve_step(cfg)
            args = (shard(specs["params"], rules.params_pspecs),
                    shard(specs["state"], rules.cache_specs),
                    shard(specs["inp"], rules.batch_specs))
            jfn = jax.jit(fn, donate_argnums=(1,))
        return jfn.lower(*args).compile()


def breakdown(hlo: str, top: int = 25) -> Tuple[List, dict]:
    an = H.HLOAnalyzer(hlo)
    totals = an.analyze()
    # multipliers per computation
    mults = {an.entry: 1.0}
    queue = [an.entry]
    while queue:
        cname = queue.pop(0)
        comp = an.comps[cname]
        m = mults[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                trips = an.trip_count(ins, comp, cond.group(1)) if cond else 1
                if body and body.group(1) not in mults:
                    mults[body.group(1)] = m * trips
                    queue.append(body.group(1))
    rows = []
    for cname, m in mults.items():
        comp = an.comps[cname]
        for ins in comp.instrs:
            if ins.opcode in H._NO_TRAFFIC or ins.opcode == "while":
                continue
            if ins.opcode == "fusion":
                mm = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                b = an._fusion_traffic(ins, comp, mm.group(1) if mm else None)
            else:
                b = H._shape_nbytes(ins.type_str)
                for o in ins.operands:
                    oi = comp.by_name.get(o)
                    if oi is not None and oi.opcode not in (
                            "constant", "tuple", "get-tuple-element"):
                        b += H._shape_nbytes(oi.type_str)
            rows.append((b * m, b, m, ins.opcode, ins.type_str[:60],
                         cname[:40]))
    rows.sort(key=lambda r: -r[0])
    return rows[:top], totals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=8)
    args = ap.parse_args()
    compiled = compile_cell(args.arch, args.shape, args.multi, args.grad_accum)
    rows, totals = breakdown(compiled.as_text(), args.top)
    mem = compiled.memory_analysis()
    print(f"temp/device: {mem.temp_size_in_bytes/2**30:.2f} GiB  "
          f"args: {mem.argument_size_in_bytes/2**30:.2f} GiB")
    print({k: (f"{v/2**30:.1f} GiB" if "bytes" in k else f"{v:.3e}")
           for k, v in totals.items()
           if k in ("flops", "hbm_bytes", "collective_bytes")})
    for r in rows:
        print(f"{r[0]/2**30:9.2f} GiB ({r[1]/2**20:9.1f} MiB x{r[2]:6.0f}) "
              f"{r[3]:14s} {r[4]:60s} {r[5]}")


if __name__ == "__main__":
    main()
