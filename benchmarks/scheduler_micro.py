"""Op-scheduling micro-benchmark (paper §2.2, Listing-1-style graphs).

Builds graphs where the traced program order hoists large allocations far
from their consumers (the pattern the paper's Listing 1 shows: broadcasts
%1084/%1085 placed early).  Measures exact peak memory of the original
order vs the symbolic schedule across dim bindings the trace never saw.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import symbolic_dims
from repro.core.ir import trace_to_graph
from repro.core.scheduling import schedule_graph, simulate_peak
from repro.core.symbolic import ShapeGraph


def listing1_style(arg0, w):
    """Large broadcasts created early, consumed late (bad program order)."""
    big1 = jnp.outer(arg0, jnp.ones((1024,), arg0.dtype))      # S0 x 1024
    big2 = jnp.outer(jnp.ones((11008,), arg0.dtype), arg0)     # 11008 x S0
    x2 = arg0.reshape(-1, 12)                                   # S1 x 12
    x3 = x2 @ w                                                 # S1 x 11008
    x4 = x3.sum(axis=1)                                         # S1
    y = (x4 ** 2).sum()
    return y + big1.sum() + big2.sum()


def chain_with_parallel_branches(x, w1, w2):
    """Two fat branches that should be evaluated one at a time."""
    a = jax.nn.relu(x @ w1)            # branch A allocations
    b = jax.nn.relu(x @ w2)
    a2 = a.sum(axis=-1)
    b2 = b.sum(axis=-1)
    return (a2 * b2).sum()


def run() -> List[Dict]:
    rows = []
    s1, = symbolic_dims("s1")
    g, _ = trace_to_graph(
        listing1_style,
        jax.ShapeDtypeStruct((12 * s1,), jnp.float32),
        jax.ShapeDtypeStruct((12, 11008), jnp.float32))
    t0 = time.time()
    res = schedule_graph(g, ShapeGraph())
    sched_ms = (time.time() - t0) * 1000
    for s1v in (64, 256, 1024):
        env = {"s1": s1v}
        before = simulate_peak(g, g.nodes, env).peak_bytes
        after = simulate_peak(g, res.order, env).peak_bytes
        rows.append(dict(graph="listing1", s1=s1v, before=before, after=after,
                         reduction=1 - after / before, sched_ms=sched_ms,
                         sym_frac=res.decision_symbolic_fraction))

    b, s = symbolic_dims("b, s")
    g2, _ = trace_to_graph(
        chain_with_parallel_branches,
        jax.ShapeDtypeStruct((b, s, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 4096), jnp.float32),
        jax.ShapeDtypeStruct((64, 4096), jnp.float32))
    res2 = schedule_graph(g2, ShapeGraph())
    for env in ({"b": 4, "s": 128}, {"b": 16, "s": 512}):
        before = simulate_peak(g2, g2.nodes, env).peak_bytes
        after = simulate_peak(g2, res2.order, env).peak_bytes
        rows.append(dict(graph="branches", s1=env["s"], before=before,
                         after=after, reduction=1 - after / before,
                         sched_ms=0, sym_frac=res2.decision_symbolic_fraction))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['graph']:10s} dim={r['s1']:5d} peak {r['before']:>12,} -> "
              f"{r['after']:>12,}  (-{100*r['reduction']:.1f}%)  "
              f"symbolic={100*r['sym_frac']:.0f}%")
