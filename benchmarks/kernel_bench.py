"""Kernel-variant selection benchmark: selected vs fixed-default config.

For each bench arch, build an attention + rmsnorm block at the arch's
real geometry (heads, head_dim, d_model from the smoke config) with
symbolic ``(b, s)`` and bucketed dispatch split at s=64, then compile it
twice:

  * **selected** — ``kernel_select=True`` (the default): the cost model
    scores the variant registry over each bucket's interval bounds and
    bakes the winner into the bucket's ``Compute`` params (the small
    bucket crosses over to the reference implementations, the large
    bucket picks bigger Pallas blocks);
  * **default** — ``kernel_select=False`` with call-site
    ``impl="pallas"``: the one fixed Pallas configuration (128-wide
    blocks) every shape used to run before per-bucket selection.

Per-call wall time is then measured with traffic pinned inside the
*small* bucket — the non-default bucket where the crossover pays — and
the large bucket is reported alongside.  Asserted (the subsystem's
headline contract): on >= 3 of the 4 archs the selected plan beats the
fixed default per call, and every winning small bucket actually selected
a non-default variant.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--json F]
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import optimize, symbolic_dims
from repro.kernels import default_variant, flash_attention, rmsnorm

ARCHS = ["llama2_1b", "gemma_2b", "granite_8b", "musicgen_medium"]

BATCH_RANGE = (1, 16)
SEQ_RANGE = (1, 2048)
BUCKET_EDGES = [64]                  # (1,64] small | (64,2048] large
SMALL_ENV = (4, 32)
LARGE_ENV = (2, 256)
MIN_ARCHS_IMPROVED = 3


def _geometry(arch: str) -> Dict[str, int]:
    cfg = get_smoke_config(arch)
    hq = cfg.n_heads
    hkv = cfg.n_kv_heads or hq
    hd = cfg.head_dim or cfg.d_model // hq
    return dict(hq=hq, hkv=hkv, hd=hd, d=cfg.d_model)


def _make_fwd(impl: Optional[str]):
    def fwd(q, k, v, x, scale):
        o = flash_attention(q, k, v, causal=True, impl=impl)
        h = rmsnorm(x, scale, impl=impl)
        return o, h
    return fwd


def _compile(arch: str, *, selected: bool):
    geo = _geometry(arch)
    B, S = symbolic_dims("b, s")
    specs = (
        jax.ShapeDtypeStruct((B, geo["hq"], S, geo["hd"]), jnp.float32),
        jax.ShapeDtypeStruct((B, geo["hkv"], S, geo["hd"]), jnp.float32),
        jax.ShapeDtypeStruct((B, geo["hkv"], S, geo["hd"]), jnp.float32),
        jax.ShapeDtypeStruct((B, S, geo["d"]), jnp.float32),
        jax.ShapeDtypeStruct((geo["d"],), jnp.float32),
    )
    fwd = _make_fwd(None if selected else "pallas")
    return optimize(fwd, *specs,
                    dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE},
                    buckets={"s": BUCKET_EDGES},
                    kernel_select=selected), geo


def _args_at(geo: Dict[str, int], b: int, s: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    f = lambda *sh: jnp.asarray(rng.standard_normal(sh, dtype=np.float32))
    return (f(b, geo["hq"], s, geo["hd"]), f(b, geo["hkv"], s, geo["hd"]),
            f(b, geo["hkv"], s, geo["hd"]), f(b, s, geo["d"]), f(geo["d"],))


def _time_calls(fn, args, *, warmup: int, reps: int) -> float:
    """Best-of-reps per-call wall seconds (post-warmup, jit caches hot)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _bucket_variants(fn, env: Dict[str, int]) -> Dict[str, str]:
    table = fn.specialization_table
    bp = table.peek(table.key_of(env))
    if bp is None or not bp.plan.kernel_selections:
        return {}
    return {s.prim_name: s.variant.name
            for s in bp.plan.kernel_selections.values()}


def run(smoke: bool = False) -> List[Dict]:
    warmup, reps = (2, 5) if smoke else (3, 20)
    rows: List[Dict] = []
    for arch in ARCHS:
        fn_sel, geo = _compile(arch, selected=True)
        fn_def, _ = _compile(arch, selected=False)
        row: Dict = dict(arch=arch, **geo)
        for label, (b, s) in (("small", SMALL_ENV), ("large", LARGE_ENV)):
            args = _args_at(geo, b, s)
            t_sel = _time_calls(fn_sel, args, warmup=warmup, reps=reps)
            t_def = _time_calls(fn_def, args, warmup=warmup, reps=reps)
            env = {"b": b, "s": s}
            row[f"{label}_env"] = [b, s]
            row[f"{label}_selected_us"] = round(t_sel * 1e6, 1)
            row[f"{label}_default_us"] = round(t_def * 1e6, 1)
            row[f"{label}_speedup"] = round(t_def / t_sel, 3)
            row[f"{label}_variants"] = _bucket_variants(fn_sel, env)
        sel = row["small_variants"]
        row["non_default"] = any(name != default_variant(prim).name
                                 for prim, name in sel.items())
        row["speedup"] = row["small_speedup"]
        row["smoke"] = smoke
        rows.append(row)

    improved = [r["arch"] for r in rows if r["small_speedup"] > 1.0]
    assert len(improved) >= MIN_ARCHS_IMPROVED, (
        f"selected variants beat the fixed default on only {improved} "
        f"(need >= {MIN_ARCHS_IMPROVED} of {ARCHS})")
    for r in rows:
        if r["small_speedup"] > 1.0:
            assert any(v.startswith("ref") for v in
                       r["small_variants"].values()), (
                f"{r['arch']}: small bucket won without selecting a "
                f"non-default variant: {r['small_variants']}")
    return rows


def format_rows(rows: List[Dict]) -> str:
    out = []
    for r in rows:
        out.append(f"{r['arch']:18s} hq={r['hq']:3d} hkv={r['hkv']:3d} "
                   f"hd={r['hd']:4d} d={r['d']:5d}")
        for label in ("small", "large"):
            b, s = r[f"{label}_env"]
            variants = " ".join(f"{k}={v}" for k, v in
                                sorted(r[f"{label}_variants"].items()))
            out.append(
                f"    {label:5s} ({b:2d},{s:4d}): "
                f"selected={r[f'{label}_selected_us']:9.1f}us "
                f"default={r[f'{label}_default_us']:9.1f}us "
                f"speedup={r[f'{label}_speedup']:6.2f}x   {variants}")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing reps (CI); same archs + asserts")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write rows as JSON")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
