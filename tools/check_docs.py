"""Docs hygiene gate (CI): snippets run, links resolve, API is covered.

Three checks, all on by default (each can run alone with its flag):

* ``--snippets`` — extract the fenced ```python blocks of ``docs/api.md``
  and execute them **in order in one shared namespace** (doctest-style:
  early blocks set up state later blocks use).  A block whose first line
  is ``# doc: skip`` is extracted but not executed (reserved for
  illustrative fragments); everything else must run.
* ``--links`` — over ``docs/*.md`` and ``README.md``: every relative
  markdown link ``[text](target)`` must resolve to an existing file, and
  every backticked file reference (``benchmarks/run.py``,
  ``memplan/arena.py``, ...) must match an existing repo file by path
  suffix — so renaming or deleting a module flags every doc that still
  names it.
* ``--coverage`` — every public symbol in ``repro.core.api.__all__``
  appears in ``docs/api.md``.

Exit status is non-zero on any failure; failures are listed, not just the
first.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import argparse
import re
import sys
import traceback
from pathlib import Path
from typing import Dict, List, Tuple

REPO = Path(__file__).resolve().parent.parent
API_DOC = REPO / "docs" / "api.md"
DOC_FILES = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

SNIPPET_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backticked tokens that look like repo file paths
FILE_REF_RE = re.compile(r"`([\w./-]+\.(?:py|md|json|yml|yaml|toml))`")


def extract_snippets(path: Path) -> List[Tuple[int, str]]:
    """(starting line number, code) for each ```python block, in order."""
    text = path.read_text()
    out = []
    for m in SNIPPET_RE.finditer(text):
        line = text.count("\n", 0, m.start()) + 2   # first line inside fence
        out.append((line, m.group(1)))
    return out


def check_snippets() -> List[str]:
    if not API_DOC.exists():
        return [f"{API_DOC} missing"]
    errors = []
    namespace: Dict = {"__name__": "__docs__"}
    snippets = extract_snippets(API_DOC)
    if not snippets:
        return [f"{API_DOC}: no ```python snippets found"]
    ran = 0
    for line, code in snippets:
        first = code.lstrip().splitlines()[0] if code.strip() else ""
        if first.startswith("# doc: skip"):
            continue
        try:
            exec(compile(code, f"{API_DOC.name}:{line}", "exec"), namespace)
            ran += 1
        except Exception:
            tb = traceback.format_exc(limit=2)
            errors.append(
                f"{API_DOC.relative_to(REPO)}:{line}: snippet raised\n{tb}")
    if not errors:
        print(f"[snippets] {ran} ran, "
              f"{len(snippets) - ran} skipped — OK")
    return errors


def _repo_files() -> List[str]:
    skip_parts = {".git", "__pycache__", ".pytest_cache"}
    return ["/" + p.relative_to(REPO).as_posix()
            for p in REPO.rglob("*") if p.is_file()
            and not skip_parts & set(p.parts)]


def check_links() -> List[str]:
    errors = []
    repo_files = _repo_files()
    n_links = n_refs = 0
    for doc in DOC_FILES:
        text = doc.read_text()
        rel = doc.relative_to(REPO)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            n_links += 1
            if not (doc.parent / path).exists():
                line = text.count("\n", 0, m.start()) + 1
                errors.append(f"{rel}:{line}: dead link -> {target}")
        for m in FILE_REF_RE.finditer(text):
            ref = m.group(1)
            if ref.startswith("."):        # e.g. `.github/...` handled below
                ref = ref.lstrip("./")
            n_refs += 1
            if not any(f.endswith("/" + ref) for f in repo_files):
                line = text.count("\n", 0, m.start()) + 1
                errors.append(f"{rel}:{line}: dead file reference `{ref}`")
    if not errors:
        print(f"[links] {n_links} links + {n_refs} file references — OK")
    return errors


def check_coverage() -> List[str]:
    sys.path.insert(0, str(REPO / "src"))
    from repro.core import api
    text = API_DOC.read_text() if API_DOC.exists() else ""
    missing = [name for name in api.__all__ if name not in text]
    if missing:
        return [f"docs/api.md misses public api symbols: {missing}"]
    print(f"[coverage] all {len(api.__all__)} repro.core.api symbols "
          f"documented — OK")
    return []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snippets", action="store_true")
    ap.add_argument("--links", action="store_true")
    ap.add_argument("--coverage", action="store_true")
    args = ap.parse_args()
    run_all = not (args.snippets or args.links or args.coverage)

    errors: List[str] = []
    if run_all or args.links:
        errors += check_links()
    if run_all or args.coverage:
        errors += check_coverage()
    if run_all or args.snippets:
        errors += check_snippets()

    if errors:
        print(f"\n{len(errors)} docs-hygiene failure(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        raise SystemExit(1)
    print("docs hygiene: all checks passed")


if __name__ == "__main__":
    main()
