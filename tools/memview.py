"""ASCII memory-timeline viewer: plan-predicted vs actual occupancy.

Renders a :class:`repro.core.obs.TimelineDiff` — the reconstructed
per-instruction device/arena occupancy of a lowered ``Program`` next to
the compile-time plan's predicted curve — as a terminal chart.  One row
per instruction: a bar of actual device bytes, a ``|`` marker where the
plan predicted that step to land, and the byte counts.

Importable (``render_timeline(diff, width=...)``) and a CLI over the
benchmark archs:

    PYTHONPATH=src python tools/memview.py --arch llama2_1b \
        --env b=8,s=512 [--width 56] [--arena]

Exit status is non-zero when the diff is not OK (actual arena peak above
the guaranteed bound, or unexplained allocations) — usable as a gate.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parent.parent


def _fmt(b: int) -> str:
    if b >= 1 << 20:
        return f"{b / (1 << 20):8.2f}M"
    if b >= 1 << 10:
        return f"{b / (1 << 10):8.1f}K"
    return f"{b:8d}B"


def render_timeline(diff, width: int = 56, arena: bool = False) -> str:
    """The diff as an ASCII chart, one row per lowered instruction.

    ``arena=True`` plots arena-backed bytes instead of total device
    bytes.  The bar is the *actual* replayed occupancy; the ``|`` marker
    is the plan's prediction for the instruction's schedule step (they
    coincide when the bar ends at the marker)."""
    actual = diff.actual
    pred = diff.predicted_arena if arena else diff.predicted_device
    curve = [(p.arena_in_use if arena else p.device_used)
             for p in actual.points]
    # scale to this env's curves — the whole-range guaranteed bound can
    # be orders of magnitude above any single env and would flatten them
    top = max(curve + pred) or 1

    def col(b: int) -> int:
        return min(width, round(b * width / top))

    kind = "arena" if arena else "device"
    lines: List[str] = []
    lines.append(f"memory timeline @ {diff.env} ({kind} bytes, "
                 f"full scale = {top:,})")
    lines.append(f"{'idx':>5} {'step':>5} {'op':<8} "
                 f"{'occupancy':<{width + 1}} {'actual':>9} {'plan':>9}")
    for pt, used in zip(actual.points, curve):
        p = pred[pt.step] if 0 <= pt.step < len(pred) else 0
        bar = list("█" * col(used) + " " * (width - col(used)) + " ")
        mark = col(p)
        bar[mark] = "|" if bar[mark] == " " else "┃"
        lines.append(f"{pt.idx:>5} {pt.step:>5} {pt.opname:<8} "
                     f"{''.join(bar)} {_fmt(used)} {_fmt(p)}")
    lines.append("")
    lines.append(diff.summary())
    for u in diff.unexplained[:10]:
        lines.append(f"  UNEXPLAINED: {u}")
    return "\n".join(lines)


def _parse_env(text: str) -> Dict[str, int]:
    env = {}
    for part in text.split(","):
        k, _, v = part.partition("=")
        env[k.strip()] = int(v)
    return env


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="llama2_1b",
                    help="benchmark arch (llama2_1b, gemma_2b, "
                         "granite_8b, musicgen_medium)")
    ap.add_argument("--env", default="b=8,s=512", metavar="b=8,s=512",
                    help="probe env as dim=value pairs")
    ap.add_argument("--width", type=int, default=56, help="bar width")
    ap.add_argument("--arena", action="store_true",
                    help="plot arena-backed bytes instead of device bytes")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO))          # benchmarks package
    sys.path.insert(0, str(REPO / "src"))
    from benchmarks.memplan_bench import (BATCH_RANGE, SEQ_RANGE,
                                          _step_and_specs)
    from repro.core import optimize

    r = _step_and_specs(args.arch)
    if r is None:
        print(f"arch {args.arch!r} has no bench model", file=sys.stderr)
        return 2
    step, specs = r
    fn = optimize(step, *specs,
                  dynamic_dims={"b": BATCH_RANGE, "s": SEQ_RANGE})
    diff = fn.memory_timeline(_parse_env(args.env))
    print(render_timeline(diff, width=args.width, arena=args.arena))
    return 0 if diff.ok else 1


if __name__ == "__main__":
    sys.exit(main())
