"""Benchmark regression guard (CI): fresh runs vs the committed JSONs.

Compares the *dimensionless* key metrics of a fresh benchmark run against
the committed ``BENCH_exec.json`` / ``BENCH_compile.json`` and fails when
any metric regresses by more than ``--threshold`` (default 25%).  Only
ratio metrics are compared — per-call speedups, overhead ratios,
miss/hit ratios — never absolute wall times, so the guard is meaningful
across machines of different speeds.

Rows are matched by their ``arch`` field; archs present on only one side
(e.g. a ``--smoke`` fresh run covering 2 of 4 archs) are skipped.  Rows
the benchmark marked ``"smoke": true`` carry single-sample medians, so
their comparisons use twice the threshold.

    PYTHONPATH=src python -m benchmarks.exec_bench --smoke --json /tmp/exec.json
    python tools/bench_regress.py --check exec=/tmp/exec.json

    PYTHONPATH=src python -m benchmarks.compile_bench --smoke --json /tmp/compile.json
    python tools/bench_regress.py --check compile=/tmp/compile.json

Exit status is non-zero on any regression; all regressions are listed.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

REPO = Path(__file__).resolve().parent.parent

# kind -> (committed file, [(dotted metric path, higher_is_better)])
KINDS: Dict[str, Tuple[str, List[Tuple[str, bool]]]] = {
    "exec": ("BENCH_exec.json", [
        # call_speedup only: the per-op overhead_ratio divides by a VM
        # overhead that is within timing noise of zero on fast machines,
        # so it swings orders of magnitude between runs
        ("call_speedup", True),          # VM per-call speedup vs reference
    ]),
    "compile": ("BENCH_compile.json", [
        ("mean_speedup", True),          # incremental vs cold pipeline
        ("scheduler.speedup", True),     # impact cache vs legacy hot loop
        ("miss_path.miss_over_hit", False),   # background serve penalty
    ]),
    "loop": ("BENCH_loop.json", [
        ("plan_size_ratio", True),       # unrolled/rolled instruction count
        ("compile_speedup_vs_unrolled", True),
        ("exec_speedup_vs_unrolled", True),
    ]),
    "bounded": ("BENCH_bounded.json", [
        # measured-tight device peak / pad-to-bound peak at 50% and 0%
        # occupancy — pure accounting, deterministic; moves only when
        # BindDim tightening or the propagation rules change
        ("tight_over_pad_half", False),
        ("tight_over_pad_empty", False),
    ]),
    "kernel": ("BENCH_kernel.json", [
        # selected plan / fixed-default plan per-call ratio on the small
        # bucket — the bucket where the variant crossover pays; the large
        # bucket sits near parity on interpret-mode hosts, so its ratio
        # is noise, not a contract
        ("speedup", True),
    ]),
    "obs": ("BENCH_obs.json", [
        # actual arena / guaranteed bound at the shared probe env —
        # deterministic, moves only when the planner or replay changes
        ("peak_over_bound", False),
        ("disabled_over_base", False),   # the <=2% telemetry contract
    ]),
    "resilience": ("BENCH_resilience.json", [
        # one transient-retry call / healthy call on the same plan —
        # ladder bookkeeping cost; the bench itself hard-asserts the
        # disabled-path <=2% contract and retry/quarantine invariants
        ("degraded_over_healthy", False),
        ("faults_mapped_frac", True),    # fired faults with structured records
    ]),
}


def _get(row: dict, path: str) -> Optional[float]:
    cur = row
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return float(cur) if isinstance(cur, (int, float)) else None


def _rows_by_arch(path: Path) -> Dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {r["arch"]: r for r in data.get("rows", []) if "arch" in r}


def check(kind: str, fresh_path: Path, committed_path: Optional[Path],
          threshold: float) -> List[str]:
    committed_file, metrics = KINDS[kind]
    committed_path = committed_path or REPO / committed_file
    if not committed_path.exists():
        return [f"{kind}: committed baseline {committed_path} is missing"]
    fresh = _rows_by_arch(fresh_path)
    committed = _rows_by_arch(committed_path)
    shared = sorted(set(fresh) & set(committed))
    if not shared:
        return [f"{kind}: no shared archs between {fresh_path} and "
                f"{committed_path}"]
    failures = []
    compared = 0
    for arch in shared:
        f_row, c_row = fresh[arch], committed[arch]
        # single-sample smoke medians are noisy: double the allowance
        tol = threshold * (2 if f_row.get("smoke") else 1)
        for path, higher_better in metrics:
            fv, cv = _get(f_row, path), _get(c_row, path)
            if fv is None or cv is None or cv == 0:
                continue
            rel = (cv - fv) / cv if higher_better else (fv - cv) / cv
            compared += 1
            status = "FAIL" if rel > tol else "ok"
            print(f"[{status}] {kind}/{arch} {path}: fresh {fv:.3f} vs "
                  f"committed {cv:.3f} ({'-' if rel > 0 else '+'}"
                  f"{abs(rel) * 100:.1f}% {'regression' if rel > 0 else 'headroom'},"
                  f" tol {tol * 100:.0f}%)")
            if rel > tol:
                failures.append(
                    f"{kind}/{arch} {path}: {fv:.3f} vs committed {cv:.3f} "
                    f"({rel * 100:.1f}% > {tol * 100:.0f}%)")
    if not compared:
        # schema drift (or a baseline for the wrong kind) must not read as
        # a clean pass
        failures.append(
            f"{kind}: no metrics compared between {fresh_path} and "
            f"{committed_path} — schema mismatch?")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="append", required=True,
                    metavar="KIND=FRESH.json",
                    help=f"kind ({'/'.join(KINDS)}) = path to a fresh run")
    ap.add_argument("--committed", default=None,
                    help="override the committed baseline path "
                         "(default: the repo's BENCH_<kind>.json)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated relative regression (default 0.25)")
    args = ap.parse_args()
    if args.committed and len(args.check) > 1:
        ap.error("--committed overrides one baseline; use it with a "
                 "single --check")

    failures: List[str] = []
    for spec in args.check:
        if "=" not in spec:
            ap.error(f"--check expects KIND=FRESH.json, got {spec!r}")
        kind, _, fresh = spec.partition("=")
        if kind not in KINDS:
            ap.error(f"unknown kind {kind!r} (known: {', '.join(KINDS)})")
        failures += check(kind, Path(fresh),
                          Path(args.committed) if args.committed else None,
                          args.threshold)
    if failures:
        print(f"\n{len(failures)} benchmark regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nno benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
